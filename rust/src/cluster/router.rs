//! Fan-out policy: which shard gets a job.
//!
//! Two forces pull on placement. Micro-batching wants *affinity*: the
//! lockstep batcher only coalesces jobs sharing a [`BatchKey`] (same
//! dimensionality, same backend — `serve::batch`), and jobs scattered
//! across shards can never meet in one shard's queue, so same-shape
//! traffic should pile onto one shard until it is actually loaded.
//! Utilization wants *spreading*: an idle shard is wasted capacity. The
//! policy here is therefore **BatchKey affinity with a least-queue-depth
//! fallback**:
//!
//! * a job whose `BatchKey` was seen before goes to the shard that key is
//!   pinned to (coalescing keeps working across processes);
//! * a new key — or an unbatchable job (fpga-sim, file datasets), which
//!   pops solo everywhere — goes to the live shard with the smallest
//!   queue depth, ties broken by lowest shard index (deterministic, and
//!   pinned by the unit tests below);
//! * a dead shard (`depth == usize::MAX`) is never chosen, and
//!   [`Router::forget_shard`] drops its pins so its keys re-home by
//!   current load after a crash.
//!
//! Depth is whatever load signal the caller trusts; the cluster front
//! feeds it `max(local in-flight count, last reported queue_depth)` — the
//! `stats` control frame's `queue_depth` field (PROTOCOL.md §6) refreshed
//! by the health poll, combined with the exact local count of
//! not-yet-answered forwards. The router is pure and single-threaded by
//! design: policy decisions are unit-testable without a socket in sight.

use std::collections::HashMap;

use crate::serve::batch::BatchKey;
use crate::serve::job::FitRequest;

/// Marks a shard the router must never pick.
pub const DEAD: usize = usize::MAX;

/// The fan-out policy state: `BatchKey → shard` pins.
#[derive(Debug, Default)]
pub struct Router {
    affinity: HashMap<BatchKey, usize>,
}

impl Router {
    pub fn new() -> Router {
        Router::default()
    }

    /// Pick a shard for `req` given per-shard depths (`DEAD` = not
    /// routable). Returns `None` only when every shard is dead.
    pub fn route(&mut self, req: &FitRequest, depths: &[usize]) -> Option<usize> {
        let key = BatchKey::of(req);
        if let Some(key) = &key {
            if let Some(&pinned) = self.affinity.get(key) {
                if depths.get(pinned).copied().unwrap_or(DEAD) != DEAD {
                    return Some(pinned);
                }
                // Pinned shard died between forget_shard sweeps: re-home.
                self.affinity.remove(key);
            }
        }
        let shard = least_loaded(depths)?;
        if let Some(key) = key {
            self.affinity.insert(key, shard);
        }
        Some(shard)
    }

    /// Drop every pin onto `shard` (it crashed or was retired); its keys
    /// re-home to the least-loaded survivor on next sight.
    pub fn forget_shard(&mut self, shard: usize) {
        self.affinity.retain(|_, &mut s| s != shard);
    }

    /// Current number of pinned keys (telemetry).
    pub fn pinned_keys(&self) -> usize {
        self.affinity.len()
    }
}

/// Smallest depth wins; ties break to the lowest index; `DEAD` entries
/// never win. `None` when nothing is routable.
fn least_loaded(depths: &[usize]) -> Option<usize> {
    depths
        .iter()
        .enumerate()
        .filter(|&(_, &d)| d != DEAD)
        .min_by_key(|&(i, &d)| (d, i))
        .map(|(i, _)| i)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn native_job(dataset: &str) -> FitRequest {
        FitRequest { dataset: dataset.into(), ..Default::default() }
    }

    fn solo_job() -> FitRequest {
        // fpga-sim has no BatchKey: always routed by load, never pinned.
        FitRequest { backend_name: "fpga-sim".into(), ..Default::default() }
    }

    #[test]
    fn same_batch_key_sticks_to_one_shard() {
        let mut r = Router::new();
        // First sight: blobs/native goes least-loaded (tie → shard 0).
        assert_eq!(r.route(&native_job("blobs"), &[0, 0]), Some(0));
        // Even with shard 1 now emptier, the key stays pinned to 0 so the
        // lockstep batcher can coalesce the stream.
        assert_eq!(r.route(&native_job("blobs"), &[5, 0]), Some(0));
        assert_eq!(r.route(&native_job("blobs"), &[9, 0]), Some(0));
        // A different key (kegg is d=20, blobs d=16) routes by load.
        assert_eq!(r.route(&native_job("kegg"), &[9, 0]), Some(1));
        assert_eq!(r.pinned_keys(), 2);
    }

    #[test]
    fn unbatchable_jobs_always_go_least_loaded() {
        let mut r = Router::new();
        assert_eq!(r.route(&solo_job(), &[3, 1]), Some(1));
        assert_eq!(r.route(&solo_job(), &[0, 1]), Some(0));
        assert_eq!(r.pinned_keys(), 0, "solo jobs never pin");
    }

    #[test]
    fn ties_break_to_the_lowest_index() {
        let mut r = Router::new();
        assert_eq!(r.route(&solo_job(), &[2, 2, 2]), Some(0));
        assert_eq!(r.route(&solo_job(), &[2, 1, 1]), Some(1));
        // A pinned key also forms on the tie-broken shard.
        assert_eq!(r.route(&native_job("blobs"), &[4, 4]), Some(0));
        assert_eq!(r.route(&native_job("blobs"), &[4, 0]), Some(0), "pin beats depth");
    }

    #[test]
    fn dead_shards_are_skipped_and_forgotten_pins_rehome() {
        let mut r = Router::new();
        assert_eq!(r.route(&native_job("blobs"), &[0, 0]), Some(0));
        // Shard 0 dies. Without a forget sweep, the stale pin is detected
        // at route time and re-homed.
        assert_eq!(r.route(&native_job("blobs"), &[DEAD, 7]), Some(1));
        // The new pin holds on shard 1.
        assert_eq!(r.route(&native_job("blobs"), &[0, 7]), Some(1));
        // forget_shard clears pins wholesale.
        r.forget_shard(1);
        assert_eq!(r.pinned_keys(), 0);
        assert_eq!(r.route(&native_job("blobs"), &[0, 7]), Some(0));
        // Everything dead: nowhere to route.
        assert_eq!(r.route(&solo_job(), &[DEAD, DEAD]), None);
        assert_eq!(r.route(&solo_job(), &[]), None);
    }

    /// Property: under arbitrary interleavings of routes, depth updates,
    /// shard deaths (with or without the `forget_shard` sweep — route-time
    /// detection must cover the sweepless case) and revivals,
    ///
    /// * a placement never targets a dead shard, and `None` is returned
    ///   exactly when every shard is dead;
    /// * a batchable key's pin is *stable*: once routed to shard `s`, it
    ///   keeps routing to `s` until `s` dies or is explicitly forgotten —
    ///   no depth change and no *other* shard's death/revival may move it
    ///   (moving a pin would silently break cross-process coalescing).
    ///
    /// The model mirrors the contract, not the implementation: it drops a
    /// key's pin when its shard dies and re-learns whatever the router
    /// picks next — so a revived shard legitimately keeping its old pin
    /// (death never observed at route time) is accepted, while any other
    /// movement fails the property.
    #[test]
    fn prop_pins_stable_and_dead_shards_never_placed() {
        use crate::serve::batch::BatchKey;
        use crate::util::proptest::run_cases;
        use std::collections::HashMap;

        let datasets = ["blobs", "kegg", "gassensor", "uscensus"];
        run_cases("router-chaos", 0xC10C_BA5E, |rng| {
            let shards = 2 + rng.next_below(4); // 2..=5
            let mut r = Router::new();
            let mut alive = vec![true; shards];
            let mut depths = vec![0usize; shards];
            let mut pins: HashMap<BatchKey, usize> = HashMap::new();
            for step in 0..60 {
                match rng.next_below(6) {
                    0 => {
                        // A shard dies; half the time the monitor's
                        // forget sweep runs, half the time the router
                        // must catch the stale pin at route time.
                        let s = rng.next_below(shards);
                        alive[s] = false;
                        if rng.next_below(2) == 0 {
                            r.forget_shard(s);
                        }
                        pins.retain(|_, &mut p| p != s);
                    }
                    1 => {
                        let s = rng.next_below(shards);
                        alive[s] = true;
                    }
                    2 => {
                        let s = rng.next_below(shards);
                        depths[s] = rng.next_below(64);
                    }
                    _ => {
                        let req = FitRequest {
                            dataset: datasets[rng.next_below(datasets.len())].into(),
                            // 1 in 4 jobs is unbatchable (fpga-sim): load-
                            // routed, never pinned.
                            backend_name: if rng.next_below(4) == 0 {
                                "fpga-sim".into()
                            } else {
                                "native".into()
                            },
                            ..Default::default()
                        };
                        let view: Vec<usize> = (0..shards)
                            .map(|i| if alive[i] { depths[i] } else { DEAD })
                            .collect();
                        let got = r.route(&req, &view);
                        if !alive.iter().any(|&a| a) {
                            if got.is_some() {
                                return Err(format!("step {step}: routed with all shards dead"));
                            }
                            continue;
                        }
                        let s = got
                            .ok_or_else(|| format!("step {step}: no route with live shards"))?;
                        if !alive[s] {
                            return Err(format!("step {step}: placed on dead shard {s}"));
                        }
                        if let Some(key) = BatchKey::of(&req) {
                            match pins.get(&key) {
                                Some(&pinned) if pinned != s => {
                                    return Err(format!(
                                        "step {step}: pin moved {pinned} -> {s} \
                                         with shard {pinned} still alive"
                                    ));
                                }
                                _ => {
                                    pins.insert(key, s);
                                }
                            }
                        }
                    }
                }
            }
            Ok(())
        });
    }
}
