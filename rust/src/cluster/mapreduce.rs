//! The front half of a map-reduce fit (PROTOCOL.md §10): partition the
//! *points* of one clustering job across shards, reduce their per-cluster
//! partial sums into new centroids each iteration, and rebroadcast until
//! convergence.
//!
//! Two drivers share the reduction arithmetic:
//!
//! * [`fit_sliced`] — the in-process reference: `S` shard-side
//!   [`PartialFitState`]s driven directly, no sockets. This is what the
//!   partition-equivalence battery (`rust/tests/mapreduce.rs`) runs
//!   against the solo `kmeans::fit`, and what the `cluster_mapreduce`
//!   bench sweeps.
//! * [`MapReduceFit`] — the wire driver the cluster front uses
//!   (`kpynq cluster --mode map-reduce`): one dedicated protocol
//!   connection per shard, `partial_fit` / `centroid_sync` frames, a
//!   straggler watchdog (read timeout → force-close → re-dispatch), and
//!   shard-loss recovery that replays the reduced-centroid history so a
//!   fresh shard lands on exactly the epoch its dead predecessor held.
//!
//! **Why the results are bit-identical to a solo fit.** Every per-point
//! assignment decision in all four algorithms is a pure function of the
//! point, its own bounds, and the shared centroid geometry — so slicing
//! the point loop changes nothing. The only cross-point arithmetic is the
//! reduction, and that runs on [`PartialAccumulator`]/[`ExactSum`]
//! superaccumulators whose merges are exactly associative: any shard
//! count, any merge order, any re-dispatch produces the same canonical
//! sums, hence the same `f64` centroids, hence the same next iteration.
//! Recovery is idempotent for the same reason — a replayed shard
//! recomputes, from the same deterministic inputs, exactly the state the
//! lost shard held.

use std::sync::Arc;
use std::time::Duration;

use crate::coordinator::driver::PartialFitState;
use crate::obs::{SpanEvent, TraceRing};
use crate::data::Dataset;
use crate::error::{Error, Result};
use crate::kmeans::reduce::{
    matrix_from_hex, matrix_to_hex, u32s_from_hex, ExactSum, PartialAccumulator,
};
use crate::kmeans::{centroid_drifts, Algorithm, FitResult, IterStats, KMeansConfig, RunStats};
use crate::serve::job::FitRequest;
use crate::util::json::Json;
use crate::util::matrix::Matrix;

use super::client::{ClientConn, ClientEvent, ReconnectPolicy};

/// Run one fit with its points partitioned across `shards` in-process
/// partial states — the reference reduction loop, bit-identical to
/// `kmeans::fit` with the same inputs (the partition-equivalence battery
/// asserts this for every algorithm × shard count).
///
/// Work counters are *not* reproduced: distributed bound state means each
/// shard prunes against its own slice, so `stats` carries only the
/// per-iteration `max_drift` (which is partition-invariant).
pub fn fit_sliced(
    algo: Algorithm,
    ds: &Dataset,
    cfg: &KMeansConfig,
    shards: usize,
) -> Result<FitResult> {
    if shards == 0 {
        return Err(Error::Config("fit_sliced needs at least one shard".into()));
    }
    let mut states = Vec::with_capacity(shards);
    for i in 0..shards {
        states.push(PartialFitState::new(algo, ds.clone(), cfg.clone(), i, shards)?);
    }
    let (k, d) = (cfg.k, ds.d());
    let mut prev = states[0].init_centroids().clone();
    let mut stats = RunStats::default();
    let (centroids, iterations, converged) = loop {
        let epoch = states[0].epoch();
        let mut acc = PartialAccumulator::new(k, d);
        for st in &mut states {
            acc.merge(&st.partial())?;
        }
        let (new_c, _) = acc.finalize(&prev);
        let (_, max_drift) = centroid_drifts(&prev, &new_c);
        stats.push(IterStats { max_drift, ..Default::default() });
        let converged = (max_drift as f64) <= cfg.tol;
        if converged || epoch >= cfg.max_iters {
            break (new_c, epoch, converged);
        }
        for st in &mut states {
            st.apply_sync(&new_c)?;
        }
        prev = new_c;
    };
    let mut assignments = Vec::with_capacity(ds.n());
    let mut inertia = ExactSum::new();
    for st in &mut states {
        let (a, s) = st.finish(&centroids)?;
        assignments.extend_from_slice(&a);
        inertia.merge(&s);
    }
    Ok(FitResult {
        centroids,
        assignments,
        inertia: inertia.value(),
        iterations,
        converged,
        stats,
    })
}

/// One shard's parsed `partial` reply (PROTOCOL.md §10).
struct PartialMsg {
    d: usize,
    counts: Vec<u64>,
    sums: String,
    /// Present only on replies to `partial_fit` (the initial centroids
    /// every shard derives identically — how the front learns `c_0`
    /// without ever loading the dataset).
    init: Option<String>,
}

/// One shard's parsed `partial_done` reply.
struct DoneMsg {
    lo: usize,
    hi: usize,
    assignments: Vec<u32>,
    inertia: ExactSum,
}

/// What one blocking read produced for a shard link.
enum Read<T> {
    Got(T),
    /// EOF, read error, or the straggler watchdog fired — the slice must
    /// be re-dispatched.
    Lost,
}

/// Per-shard wire state: the dedicated connection plus its remaining
/// re-dispatch budget.
struct ShardSlot {
    addr: String,
    conn: ClientConn,
    budget: u32,
}

/// The socket-level map-reduce driver (PROTOCOL.md §10): owns the
/// iteration barrier across `addrs.len()` shard daemons, the straggler
/// watchdog, and shard-loss recovery. Construct with [`MapReduceFit::new`],
/// adjust the public knobs, then [`MapReduceFit::run`].
///
/// Sizing note: partial frames carry `k·d` exact sums at 160 hex chars
/// each and `partial_done` carries the slice's assignment vector, all
/// under the protocol's 64 KiB line cap — map-reduce jobs are bounded to
/// roughly `k·d ≤ 400` and ~8000 points per slice at revision 1 framing
/// (PROTOCOL.md §10 documents the limit).
pub struct MapReduceFit {
    /// The §3 job body; `req.id` is used verbatim as the wire id on every
    /// §10 frame (no remapping — this driver owns its connections).
    pub req: FitRequest,
    pub algo: Algorithm,
    /// One shard daemon address per slice, in shard order.
    pub addrs: Vec<String>,
    pub reconnect: ReconnectPolicy,
    /// Straggler watchdog: a shard that produces nothing on its link for
    /// this long is force-closed and its slice re-dispatched.
    pub shard_timeout: Duration,
    /// Re-dispatches allowed per shard before the fit fails.
    pub redispatch_budget: u32,
    /// When set, every epoch's reduce barrier appends a `reduce-barrier`
    /// span under the given trace id (PROTOCOL.md §11) — the cluster
    /// front passes its own ring and the job's trace id here.
    pub trace: Option<(Arc<TraceRing>, String)>,
}

impl MapReduceFit {
    pub fn new(req: FitRequest, addrs: Vec<String>) -> MapReduceFit {
        MapReduceFit {
            req,
            algo: Algorithm::Yinyang,
            addrs,
            reconnect: ReconnectPolicy::default(),
            shard_timeout: Duration::from_secs(30),
            redispatch_budget: 3,
            trace: None,
        }
    }

    /// Drive the fit to completion: fan out `partial_fit`, reduce each
    /// epoch's partials into new centroids, rebroadcast via
    /// `centroid_sync`, and seal with `done: true` once converged (or at
    /// the iteration cap). Returns the assembled [`FitResult`] —
    /// bit-identical to the solo fit with the same request parameters.
    pub fn run(&self) -> Result<FitResult> {
        let s = self.addrs.len();
        if s == 0 {
            return Err(Error::Config("map-reduce fit needs at least one shard".into()));
        }
        let k = self.req.kmeans.k;
        let mut slots = Vec::with_capacity(s);
        for addr in &self.addrs {
            slots.push(ShardSlot {
                addr: addr.clone(),
                conn: self.connect(addr)?,
                budget: self.redispatch_budget,
            });
        }
        for (i, slot) in slots.iter_mut().enumerate() {
            // A failed send surfaces as a lost link at collect time.
            let _ = slot.conn.send_frame(&self.partial_fit_frame(i, s, &[]));
        }

        // Reduced centroid sets c_1..c_{t-1}, hex, oldest first — exactly
        // the §10 `history` a re-dispatched shard replays.
        let mut history: Vec<String> = Vec::new();
        let mut init: Option<Matrix> = None;
        let mut d = 0usize;
        let mut prev: Option<Matrix> = None;
        let mut stats = RunStats::default();
        let (centroids, iterations, converged) = loop {
            let epoch = history.len() + 1;
            let mut acc: Option<PartialAccumulator> = None;
            for i in 0..s {
                let msg = self.collect_partial(&mut slots[i], i, s, epoch, &history)?;
                if init.is_none() {
                    d = msg.d;
                    let hex = msg.init.as_ref().ok_or_else(|| {
                        Error::Parse("first partial reply carries no init centroids".into())
                    })?;
                    init = Some(matrix_from_hex(hex, k, d)?);
                }
                let part = PartialAccumulator::from_wire(k, d, &msg.counts, &msg.sums)?;
                match &mut acc {
                    None => acc = Some(part),
                    Some(a) => a.merge(&part)?,
                }
            }
            let acc = acc.expect("at least one shard reduced");
            let base = prev.as_ref().unwrap_or_else(|| init.as_ref().expect("init learned"));
            let (new_c, _) = acc.finalize(base);
            let (_, max_drift) = centroid_drifts(base, &new_c);
            stats.push(IterStats { max_drift, ..Default::default() });
            if let Some((ring, trace_id)) = &self.trace {
                if !trace_id.is_empty() {
                    ring.push(
                        SpanEvent::new(trace_id, "reduce-barrier")
                            .num("epoch", epoch as f64)
                            .num("max_drift", max_drift as f64),
                    );
                }
            }
            let converged = (max_drift as f64) <= self.req.kmeans.tol;
            if converged || epoch >= self.req.kmeans.max_iters {
                break (new_c, epoch, converged);
            }
            let frame = self.sync_frame(epoch, &new_c, false);
            for slot in &mut slots {
                let _ = slot.conn.send_frame(&frame);
            }
            history.push(matrix_to_hex(&new_c));
            prev = Some(new_c);
        };

        // Done phase: seal every slice against the final centroids.
        let done = self.sync_frame(iterations, &centroids, true);
        for slot in &mut slots {
            let _ = slot.conn.send_frame(&done);
        }
        let mut assignments = Vec::new();
        let mut inertia = ExactSum::new();
        let mut cursor = 0usize;
        for (i, slot) in slots.iter_mut().enumerate() {
            let msg = self.collect_done(slot, i, s, iterations, &history, &done)?;
            if msg.lo != cursor {
                return Err(Error::Parse(format!(
                    "shard {i} sealed slice [{}, {}), expected it to start at {cursor}",
                    msg.lo, msg.hi
                )));
            }
            cursor = msg.hi;
            assignments.extend_from_slice(&msg.assignments);
            inertia.merge(&msg.inertia);
        }
        Ok(FitResult {
            centroids,
            assignments,
            inertia: inertia.value(),
            iterations,
            converged,
            stats,
        })
    }

    fn connect(&self, addr: &str) -> Result<ClientConn> {
        let conn = ClientConn::connect_with_backoff(addr, &self.reconnect, || None)?;
        conn.set_read_timeout(Some(self.shard_timeout))?;
        Ok(conn)
    }

    /// The §10 `partial_fit` frame: the §3 job body plus the op-specific
    /// keys (and the replay history when re-dispatching).
    fn partial_fit_frame(&self, shard_index: usize, shard_count: usize, history: &[String]) -> Json {
        let mut m = match self.req.to_json() {
            Json::Obj(m) => m,
            _ => unreachable!("FitRequest::to_json returns an object"),
        };
        m.insert("op".into(), Json::Str("partial_fit".into()));
        m.insert("algorithm".into(), Json::Str(self.algo.name().into()));
        m.insert("shard_index".into(), Json::Num(shard_index as f64));
        m.insert("shard_count".into(), Json::Num(shard_count as f64));
        if !history.is_empty() {
            m.insert("history".into(), Json::Str(history.concat()));
        }
        Json::Obj(m)
    }

    fn sync_frame(&self, epoch: usize, centroids: &Matrix, done: bool) -> Json {
        let mut m = std::collections::BTreeMap::new();
        m.insert("op".into(), Json::Str("centroid_sync".into()));
        m.insert("id".into(), Json::Num(self.req.id as f64));
        m.insert("epoch".into(), Json::Num(epoch as f64));
        m.insert("centroids".into(), Json::Str(matrix_to_hex(centroids)));
        m.insert("done".into(), Json::Bool(done));
        Json::Obj(m)
    }

    /// Await shard `i`'s `partial` for `epoch`; on loss, re-dispatch the
    /// slice (with history) until the budget runs out.
    fn collect_partial(
        &self,
        slot: &mut ShardSlot,
        i: usize,
        s: usize,
        epoch: usize,
        history: &[String],
    ) -> Result<PartialMsg> {
        match self.await_partial(slot, i, epoch)? {
            Read::Got(msg) => Ok(msg),
            Read::Lost => self.redispatch(slot, i, s, epoch, history),
        }
    }

    /// Re-dispatch shard `i`'s slice onto a fresh connection: reconnect
    /// under the backoff policy, resend `partial_fit` with the reduced-
    /// centroid history, and await the replayed `partial` — which lands on
    /// exactly the epoch the lost incarnation held (replay is
    /// deterministic, so recovery is idempotent; PROTOCOL.md §10).
    fn redispatch(
        &self,
        slot: &mut ShardSlot,
        i: usize,
        s: usize,
        epoch: usize,
        history: &[String],
    ) -> Result<PartialMsg> {
        loop {
            if slot.budget == 0 {
                return Err(Error::Config(format!(
                    "shard {i} ({}) lost and re-dispatch budget exhausted",
                    slot.addr
                )));
            }
            slot.budget -= 1;
            slot.conn.shutdown_handle().shutdown();
            slot.conn = match self.connect(&slot.addr) {
                Ok(c) => c,
                Err(e) => {
                    if slot.budget == 0 {
                        return Err(e);
                    }
                    continue;
                }
            };
            let _ = slot.conn.send_frame(&self.partial_fit_frame(i, s, history));
            if let Read::Got(msg) = self.await_partial(slot, i, epoch)? {
                return Ok(msg);
            }
        }
    }

    /// Await shard `i`'s `partial_done`; on loss, re-dispatch (full
    /// history replay), discard the replayed `partial`, resend the done
    /// sync, and await again.
    fn collect_done(
        &self,
        slot: &mut ShardSlot,
        i: usize,
        s: usize,
        epoch: usize,
        history: &[String],
        done_frame: &Json,
    ) -> Result<DoneMsg> {
        loop {
            match self.await_done(slot, i)? {
                Read::Got(msg) => return Ok(msg),
                Read::Lost => {
                    // The replayed partial (epoch == `epoch`) is consumed
                    // and discarded here; its sums were already reduced.
                    self.redispatch(slot, i, s, epoch, history)?;
                    let _ = slot.conn.send_frame(done_frame);
                }
            }
        }
    }

    /// One blocking read loop for a `partial` frame. Protocol-error
    /// replies are fatal (the shard rejected a frame deterministically —
    /// re-dispatching would reproduce the rejection); EOF, read errors and
    /// watchdog ticks report the link lost.
    fn await_partial(&self, slot: &mut ShardSlot, i: usize, epoch: usize) -> Result<Read<PartialMsg>> {
        loop {
            match slot.conn.next_event() {
                Ok(ClientEvent::Notice(j)) => {
                    let op = j.get("op").ok().and_then(|v| v.as_str().ok().map(str::to_string));
                    if op.as_deref() == Some("partial") {
                        return Ok(Read::Got(self.parse_partial(&j, i, epoch)?));
                    }
                    // Unrelated notices (idle-timeout warnings etc.): skip.
                }
                Ok(ClientEvent::ProtocolError(j)) => {
                    let msg = j
                        .get("error")
                        .ok()
                        .and_then(|v| v.as_str().ok().map(str::to_string))
                        .unwrap_or_else(|| j.to_string());
                    return Err(Error::Parse(format!("shard {i} rejected frame: {msg}")));
                }
                Ok(ClientEvent::Tick) => {
                    // Straggler watchdog: force-close so both halves EOF,
                    // then let the caller re-dispatch.
                    slot.conn.shutdown_handle().shutdown();
                    return Ok(Read::Lost);
                }
                Ok(ClientEvent::Eof) | Err(_) => return Ok(Read::Lost),
                Ok(_) => {} // pongs, job responses: not ours, skip
            }
        }
    }

    fn await_done(&self, slot: &mut ShardSlot, i: usize) -> Result<Read<DoneMsg>> {
        loop {
            match slot.conn.next_event() {
                Ok(ClientEvent::Notice(j)) => {
                    let op = j.get("op").ok().and_then(|v| v.as_str().ok().map(str::to_string));
                    if op.as_deref() == Some("partial_done") {
                        return Ok(Read::Got(self.parse_done(&j, i)?));
                    }
                }
                Ok(ClientEvent::ProtocolError(j)) => {
                    let msg = j
                        .get("error")
                        .ok()
                        .and_then(|v| v.as_str().ok().map(str::to_string))
                        .unwrap_or_else(|| j.to_string());
                    return Err(Error::Parse(format!("shard {i} rejected frame: {msg}")));
                }
                Ok(ClientEvent::Tick) => {
                    slot.conn.shutdown_handle().shutdown();
                    return Ok(Read::Lost);
                }
                Ok(ClientEvent::Eof) | Err(_) => return Ok(Read::Lost),
                Ok(_) => {}
            }
        }
    }

    fn parse_partial(&self, j: &Json, shard_index: usize, epoch: usize) -> Result<PartialMsg> {
        if j.get("id")?.as_usize()? as u64 != self.req.id {
            return Err(Error::Parse("partial reply carries a foreign id".into()));
        }
        if j.get("shard_index")?.as_usize()? != shard_index {
            return Err(Error::Parse(format!(
                "partial reply from the wrong shard (expected {shard_index})"
            )));
        }
        let got = j.get("epoch")?.as_usize()?;
        if got != epoch {
            return Err(Error::Parse(format!(
                "partial reply for epoch {got}, expected {epoch}"
            )));
        }
        let counts = j
            .get("counts")?
            .as_arr()?
            .iter()
            .map(|v| Ok(v.as_usize()? as u64))
            .collect::<Result<Vec<u64>>>()?;
        Ok(PartialMsg {
            d: j.get("d")?.as_usize()?,
            counts,
            sums: j.get("sums")?.as_str()?.to_string(),
            init: j.get("init").ok().and_then(|v| v.as_str().ok().map(str::to_string)),
        })
    }

    fn parse_done(&self, j: &Json, shard_index: usize) -> Result<DoneMsg> {
        if j.get("id")?.as_usize()? as u64 != self.req.id {
            return Err(Error::Parse("partial_done reply carries a foreign id".into()));
        }
        if j.get("shard_index")?.as_usize()? != shard_index {
            return Err(Error::Parse(format!(
                "partial_done reply from the wrong shard (expected {shard_index})"
            )));
        }
        Ok(DoneMsg {
            lo: j.get("lo")?.as_usize()?,
            hi: j.get("hi")?.as_usize()?,
            assignments: u32s_from_hex(j.get("assignments")?.as_str()?)?,
            inertia: ExactSum::from_hex(j.get("inertia")?.as_str()?)?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::kmeans::{self, KMeansConfig};
    use crate::serve::job::assignments_checksum;

    #[test]
    fn one_shard_slicing_is_the_solo_fit() {
        let ds = synth::blobs(200, 8, 4, 7);
        let cfg = KMeansConfig { k: 4, seed: 11, ..Default::default() };
        for algo in Algorithm::ALL {
            let solo = kmeans::fit(algo, &ds, &cfg).unwrap();
            let sliced = fit_sliced(algo, &ds, &cfg, 1).unwrap();
            assert_eq!(solo.assignments, sliced.assignments, "{}", algo.name());
            assert_eq!(
                solo.centroids.as_slice(),
                sliced.centroids.as_slice(),
                "{}",
                algo.name()
            );
            assert_eq!(solo.inertia.to_bits(), sliced.inertia.to_bits(), "{}", algo.name());
            assert_eq!(
                assignments_checksum(&solo.assignments),
                assignments_checksum(&sliced.assignments)
            );
        }
    }

    #[test]
    fn more_shards_than_points_leaves_empty_slices_harmless() {
        // n=3 across 5 shards: two slices are empty; their partials are
        // all-zero and must not poison the reduction with NaNs.
        let ds = synth::blobs(3, 2, 2, 5);
        let cfg = KMeansConfig { k: 2, seed: 3, max_iters: 10, ..Default::default() };
        let solo = kmeans::fit(Algorithm::Lloyd, &ds, &cfg).unwrap();
        let sliced = fit_sliced(Algorithm::Lloyd, &ds, &cfg, 5).unwrap();
        assert_eq!(solo.assignments, sliced.assignments);
        assert_eq!(solo.centroids.as_slice(), sliced.centroids.as_slice());
        assert!(sliced.centroids.as_slice().iter().all(|v| v.is_finite()));
        assert_eq!(solo.inertia.to_bits(), sliced.inertia.to_bits());
    }

    #[test]
    fn zero_shards_is_a_config_error() {
        let ds = synth::blobs(10, 2, 2, 1);
        let cfg = KMeansConfig { k: 2, ..Default::default() };
        assert!(fit_sliced(Algorithm::Lloyd, &ds, &cfg, 0).is_err());
    }
}
