//! Shard process lifecycle: spawn, health, restart, reap.
//!
//! Each shard is a whole `kpynq serve --listen unix:<dir>/shard-<i>.sock`
//! child process with its own engine banks — the cross-process analogue
//! of PR 2's in-process worker shards, so warm-engine amortization scales
//! past one address space (DESIGN.md §2). The [`Supervisor`] owns the
//! `std::process::Child` handles and nothing else: readiness waits,
//! respawn budgets and zombie reaping live here, while in-flight-job
//! bookkeeping (what must be requeued when a shard dies) stays with the
//! cluster front, which is the only component that knows what each shard
//! was sent.
//!
//! Readiness is protocol-level, not process-level: a shard counts as up
//! when a [`ClientConn`] completes the PROTOCOL.md §2 greeting +
//! handshake over its socket — the same connection the front then keeps
//! as the shard's forwarding link, so there is no separate health port to
//! drift from reality. Liveness after that is watched two ways: the
//! link's reader sees EOF the moment the process dies, and the front's
//! periodic poll calls [`Supervisor::reap_exited`] to catch children that
//! exited without ever owning a socket.

use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::time::Duration;

use crate::error::{Error, Result};
use crate::obs;
use crate::serve::ServeConfig;

use super::client::{ClientConn, ReconnectPolicy};

/// How a shard process is launched.
#[derive(Clone, Debug)]
pub struct SupervisorConfig {
    /// The `kpynq` binary to exec. Defaults to the current executable —
    /// right for `kpynq cluster`; tests point it at `CARGO_BIN_EXE_kpynq`.
    pub program: PathBuf,
    /// Directory for `shard-<i>.sock` listener sockets.
    pub socket_dir: PathBuf,
    /// Per-shard pool shape, forwarded as `--workers/--queue/--batch/--shed`.
    pub serve: ServeConfig,
    /// Respawns allowed per shard before it is abandoned as dead.
    pub max_restarts: u32,
    /// Connect-retry shape for a freshly spawned shard (vetoed early if
    /// the child exits). The [`ReconnectPolicy`] default *is* the
    /// readiness shape this module used to hard-code: doubling backoff
    /// from 20 ms capped at 250 ms, 45 attempts, ≈ 10 s total.
    /// Deliberately bounded: a respawn runs this inline on the cluster's
    /// monitor thread, which is stalled for the duration.
    pub reconnect: ReconnectPolicy,
}

struct ShardProc {
    child: Child,
    socket: PathBuf,
    restarts: u32,
    /// Bumped on every (re)spawn; stale crash reports from a link of an
    /// earlier incarnation are ignored by generation.
    generation: u64,
    /// Past its restart budget: the reaper stops reporting it and
    /// `respawn` refuses it.
    abandoned: bool,
    /// This incarnation was killed *by us* (health watchdog / chaos
    /// hook), not by a crash of its own: its respawn is budget-free, so
    /// a slow-but-healthy shard repeatedly reaped by the watchdog can
    /// never spiral into permanent abandonment — the budget only counts
    /// deaths the shard caused itself.
    killed_by_supervisor: bool,
}

/// Owns the shard child processes of one cluster.
pub struct Supervisor {
    cfg: SupervisorConfig,
    shards: Vec<ShardProc>,
    restarts_total: u64,
}

impl Supervisor {
    /// Spawn `shards` children and wait until each one speaks the
    /// protocol; returns the supervisor plus one ready connection per
    /// shard (in shard order). Any startup failure kills what was already
    /// spawned — a half-up cluster is refused, not served.
    pub fn spawn(cfg: SupervisorConfig, shards: usize) -> Result<(Supervisor, Vec<ClientConn>)> {
        if shards == 0 {
            return Err(Error::Config("cluster shards must be positive".into()));
        }
        std::fs::create_dir_all(&cfg.socket_dir)?;
        let mut sup = Supervisor { cfg, shards: Vec::with_capacity(shards), restarts_total: 0 };
        let mut conns = Vec::with_capacity(shards);
        for index in 0..shards {
            match sup.spawn_one(index) {
                Ok((proc_, conn)) => {
                    sup.shards.push(proc_);
                    conns.push(conn);
                }
                Err(e) => {
                    sup.kill_all();
                    return Err(e);
                }
            }
        }
        Ok((sup, conns))
    }

    /// The `unix:<path>` address of shard `index`.
    pub fn socket_addr(&self, index: usize) -> String {
        format!("unix:{}", self.shards[index].socket.display())
    }

    /// OS pid of shard `index`'s current incarnation.
    pub fn pid(&self, index: usize) -> u32 {
        self.shards[index].child.id()
    }

    /// Current spawn generation of shard `index`.
    pub fn generation(&self, index: usize) -> u64 {
        self.shards[index].generation
    }

    /// Total respawns performed over the cluster's lifetime.
    pub fn restarts_total(&self) -> u64 {
        self.restarts_total
    }

    /// SIGKILL shard `index` (fault injection / last-resort teardown).
    /// The crash is observed and recovered through the normal path: the
    /// shard's link sees EOF and reports it.
    pub fn kill(&mut self, index: usize) {
        let s = &mut self.shards[index];
        obs::log::warn(
            "cluster.supervisor",
            &format!("killing shard {index} pid {} (watchdog/chaos)", s.child.id()),
        );
        s.killed_by_supervisor = true;
        let _ = s.child.kill();
        let _ = s.child.wait(); // reap; a later respawn must not see a zombie
    }

    /// Sweep for children that exited on their own; returns
    /// `(index, generation)` of each newly dead shard. (Crashes are
    /// usually seen first by the shard's link reader — this catches a
    /// child that died without ever serving its socket.)
    pub fn reap_exited(&mut self) -> Vec<(usize, u64)> {
        let mut dead = Vec::new();
        for (i, s) in self.shards.iter_mut().enumerate() {
            if s.abandoned {
                continue;
            }
            if let Ok(Some(_)) = s.child.try_wait() {
                dead.push((i, s.generation));
            }
        }
        dead
    }

    /// Stop supervising shard `index` for good (its restart budget is
    /// spent, or it cannot be respawned); the reaper ignores it from now
    /// on and `respawn` refuses it.
    pub fn abandon(&mut self, index: usize) {
        let s = &mut self.shards[index];
        obs::log::warn(
            "cluster.supervisor",
            &format!("abandoning shard {index} after {} restarts", s.restarts),
        );
        s.abandoned = true;
        let _ = s.child.kill();
        let _ = s.child.wait();
    }

    /// Replace a dead shard with a fresh incarnation and return a ready
    /// connection to it. Fails once the shard's respawn budget
    /// (`max_restarts`) is exhausted — the caller then requeues its work
    /// onto the survivors and routes around it.
    pub fn respawn(&mut self, index: usize) -> Result<ClientConn> {
        if self.shards[index].abandoned {
            return Err(Error::Config(format!("shard {index} was abandoned")));
        }
        // Supervisor-initiated kills (watchdog, chaos) respawn for free;
        // only self-inflicted deaths consume the budget.
        let budgeted = !self.shards[index].killed_by_supervisor;
        if budgeted && self.shards[index].restarts >= self.cfg.max_restarts {
            return Err(Error::Config(format!(
                "shard {index} exceeded its restart budget ({})",
                self.cfg.max_restarts
            )));
        }
        // Reap whatever is left of the old incarnation.
        let _ = self.shards[index].child.kill();
        let _ = self.shards[index].child.wait();
        let restarts = self.shards[index].restarts + if budgeted { 1 } else { 0 };
        let generation = self.shards[index].generation + 1;
        let (mut proc_, conn) = self.spawn_one(index)?;
        proc_.restarts = restarts;
        proc_.generation = generation;
        self.restarts_total += 1;
        obs::log::info(
            "cluster.supervisor",
            &format!(
                "respawned shard {index} pid {} generation {generation} ({} restart(s) used)",
                proc_.child.id(),
                restarts
            ),
        );
        self.shards[index] = proc_;
        Ok(conn)
    }

    /// Wait for every child to exit within `grace` (the caller has
    /// already sent each one `{"op":"shutdown"}`); stragglers are killed.
    pub fn shutdown(mut self, grace: Duration) {
        let deadline = std::time::Instant::now() + grace;
        loop {
            let all_done = self
                .shards
                .iter_mut()
                .all(|s| matches!(s.child.try_wait(), Ok(Some(_))));
            if all_done {
                break;
            }
            if std::time::Instant::now() >= deadline {
                self.kill_all();
                break;
            }
            std::thread::sleep(Duration::from_millis(20));
        }
        for s in &self.shards {
            let _ = std::fs::remove_file(&s.socket);
        }
    }

    fn kill_all(&mut self) {
        for s in &mut self.shards {
            let _ = s.child.kill();
            let _ = s.child.wait();
        }
    }

    /// Spawn shard `index` and block until it speaks the protocol.
    fn spawn_one(&self, index: usize) -> Result<(ShardProc, ClientConn)> {
        let socket = self.cfg.socket_dir.join(format!("shard-{index}.sock"));
        // A stale socket from a previous incarnation would let the connect
        // loop reach a dead listener; the daemon also clears it, but only
        // once it gets as far as binding.
        let _ = std::fs::remove_file(&socket);
        let addr = format!("unix:{}", socket.display());
        let serve = &self.cfg.serve;
        let mut child = Command::new(&self.cfg.program)
            .arg("serve")
            .arg("--listen")
            .arg(&addr)
            .arg("--workers")
            .arg(serve.workers.to_string())
            .arg("--queue")
            .arg(serve.queue_capacity.to_string())
            .arg("--batch")
            .arg(serve.max_batch.to_string())
            .arg("--shed")
            .arg(serve.shed_policy.name())
            // The shard's stdio is not ours to inherit: stdout is unused by
            // the daemon, and a piped stderr nobody drains would wedge the
            // child on its first report write.
            .stdin(Stdio::null())
            .stdout(Stdio::null())
            .stderr(Stdio::null())
            .spawn()
            .map_err(|e| {
                Error::Config(format!(
                    "cannot spawn shard {index} ({}): {e}",
                    self.cfg.program.display()
                ))
            })?;
        let conn = ClientConn::connect_with_backoff(
            &addr,
            &self.cfg.reconnect,
            || match child.try_wait() {
                Ok(Some(status)) => Some(format!("shard {index} exited during startup: {status}")),
                _ => None,
            },
        );
        match conn {
            Ok(conn) => {
                obs::log::debug(
                    "cluster.supervisor",
                    &format!("shard {index} up: pid {} at {addr}", child.id()),
                );
                Ok((
                    ShardProc {
                        child,
                        socket,
                        restarts: 0,
                        generation: 0,
                        abandoned: false,
                        killed_by_supervisor: false,
                    },
                    conn,
                ))
            }
            Err(e) => {
                let _ = child.kill();
                let _ = child.wait();
                Err(e)
            }
        }
    }
}

/// The default shard program: this very binary (`kpynq cluster` re-execs
/// itself as `kpynq serve`).
pub fn default_program() -> PathBuf {
    std::env::current_exe().unwrap_or_else(|_| PathBuf::from("kpynq"))
}
