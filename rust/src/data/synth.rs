//! Deterministic synthetic dataset generators.
//!
//! Six named generators stand in for the paper's six UCI datasets
//! (DESIGN.md §3). Each reproduces the axes that matter to a
//! triangle-inequality K-means evaluation — size `n`, dimensionality `d`,
//! number of natural modes, mode separation and imbalance — because those
//! are what determine both the distance-computation count of standard
//! K-means and the hit rate of the multi-level filters.
//!
//! All generators are pure functions of their seed.

use crate::data::Dataset;
use crate::util::matrix::Matrix;
use crate::util::rng::Rng;

/// Specification of a Gaussian-mixture generator.
#[derive(Clone, Debug)]
pub struct MixtureSpec {
    pub name: &'static str,
    pub n: usize,
    pub d: usize,
    /// Number of generating modes (not necessarily the k used at fit time).
    pub modes: usize,
    /// Mode-center spread (box half-width the centers are drawn from).
    pub center_spread: f32,
    /// Per-mode point noise std, as a fraction of `center_spread`.
    pub noise_frac: f32,
    /// Dirichlet-ish imbalance: 0 = balanced, 1 = heavily skewed.
    pub imbalance: f32,
    /// Fraction of dimensions carrying structure (rest is isotropic noise),
    /// mimicking real tabular data where most variance lives in a subspace.
    pub active_dims_frac: f32,
}

impl MixtureSpec {
    /// Generate the dataset for this spec.
    pub fn generate(&self, seed: u64) -> Dataset {
        let mut rng = Rng::new(seed ^ fnv(self.name));
        let modes = self.modes.max(1);
        let active = ((self.d as f32 * self.active_dims_frac).ceil() as usize)
            .clamp(1, self.d);

        // Mode centers: uniform in a box, but only in active dimensions.
        let mut centers = vec![0.0f32; modes * self.d];
        for m in 0..modes {
            for j in 0..active {
                centers[m * self.d + j] =
                    (rng.next_f32() * 2.0 - 1.0) * self.center_spread;
            }
        }

        // Mode weights: geometric decay controlled by `imbalance`.
        let decay = 1.0 - 0.85 * self.imbalance as f64;
        let weights: Vec<f64> = (0..modes).map(|m| decay.powi(m as i32)).collect();

        let noise = self.center_spread * self.noise_frac;
        let mut data = vec![0.0f32; self.n * self.d];
        let mut labels = vec![0u32; self.n];
        for i in 0..self.n {
            let m = rng.sample_weighted(&weights);
            labels[i] = m as u32;
            let row = &mut data[i * self.d..(i + 1) * self.d];
            for j in 0..self.d {
                let center = centers[m * self.d + j];
                // Inactive dims get pure small-amplitude noise.
                let sigma = if j < active { noise } else { noise * 0.3 };
                row[j] = center + rng.normal_f32(0.0, sigma);
            }
        }

        let mut ds = Dataset::new(
            self.name,
            Matrix::from_vec(data, self.n, self.d).expect("sized by construction"),
        );
        ds.labels = Some(labels);
        ds
    }
}

/// FNV-1a of the generator name, mixed into the seed so different datasets
/// never share a random stream even with the same user seed.
fn fnv(s: &str) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// The six UCI-equivalent specs (DESIGN.md §3).
pub fn uci_specs() -> Vec<MixtureSpec> {
    vec![
        // Gas Sensor Array Drift: 13,910 × 128 chemosensor features; strong
        // batch structure → well separated modes.
        MixtureSpec {
            name: "gassensor",
            n: 13_910,
            d: 128,
            modes: 24,
            center_spread: 10.0,
            noise_frac: 0.06,
            imbalance: 0.3,
            active_dims_frac: 0.5,
        },
        // KEGG Metabolic Reaction Network (directed): 53,413 × 20 graph
        // statistics; low-d, skewed mass.
        MixtureSpec {
            name: "kegg",
            n: 53_413,
            d: 20,
            modes: 20,
            center_spread: 8.0,
            noise_frac: 0.12,
            imbalance: 0.6,
            active_dims_frac: 0.8,
        },
        // 3D Road Network (North Jutland): 434,874 × 3 coordinates; huge n,
        // tiny d, spatially smooth → overlapping modes.
        MixtureSpec {
            name: "roadnetwork",
            n: 434_874,
            d: 3,
            modes: 40,
            center_spread: 6.0,
            noise_frac: 0.35,
            imbalance: 0.2,
            active_dims_frac: 1.0,
        },
        // US Census 1990 (projected): 100,000 × 68 categorical-derived dims.
        MixtureSpec {
            name: "uscensus",
            n: 100_000,
            d: 68,
            modes: 32,
            center_spread: 5.0,
            noise_frac: 0.25,
            imbalance: 0.4,
            active_dims_frac: 0.6,
        },
        // Covertype: 150,000 (subsampled from 581k) × 54 cartographic
        // features; heavy class imbalance.
        MixtureSpec {
            name: "covtype",
            n: 150_000,
            d: 54,
            modes: 7,
            center_spread: 7.0,
            noise_frac: 0.2,
            imbalance: 0.8,
            active_dims_frac: 0.7,
        },
        // MNIST after a 64-d projection (papers use PCA-64): 60,000 × 64
        // with ten digit modes.
        MixtureSpec {
            name: "mnist",
            n: 60_000,
            d: 64,
            modes: 10,
            center_spread: 9.0,
            noise_frac: 0.18,
            imbalance: 0.1,
            active_dims_frac: 0.9,
        },
    ]
}

/// Generate one of the six UCI-equivalents by name.
pub fn uci(name: &str, seed: u64) -> Option<Dataset> {
    uci_specs()
        .into_iter()
        .find(|s| s.name == name)
        .map(|s| s.generate(seed))
}

/// All six UCI-equivalents.
pub fn uci_all(seed: u64) -> Vec<Dataset> {
    uci_specs().into_iter().map(|s| s.generate(seed)).collect()
}

/// Simple well-separated blobs (tests, quickstart).
pub fn blobs(n: usize, d: usize, modes: usize, seed: u64) -> Dataset {
    MixtureSpec {
        name: "blobs",
        n,
        d,
        modes,
        center_spread: 10.0,
        noise_frac: 0.04,
        imbalance: 0.0,
        active_dims_frac: 1.0,
    }
    .generate(seed)
}

/// Uniform noise — the adversarial case where triangle-inequality filters
/// help least (used by the ablation benches as a lower bound).
pub fn uniform(n: usize, d: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed ^ fnv("uniform"));
    let data: Vec<f32> = (0..n * d).map(|_| rng.next_f32() * 2.0 - 1.0).collect();
    Dataset::new("uniform", Matrix::from_vec(data, n, d).unwrap())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::matrix::sq_dist;

    #[test]
    fn generators_are_deterministic() {
        let a = uci("kegg", 42).unwrap();
        let b = uci("kegg", 42).unwrap();
        assert_eq!(a.points, b.points);
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn different_seeds_differ() {
        let a = blobs(100, 4, 3, 1);
        let b = blobs(100, 4, 3, 2);
        assert_ne!(a.points, b.points);
    }

    #[test]
    fn all_six_specs_have_paper_shapes() {
        let specs = uci_specs();
        assert_eq!(specs.len(), 6);
        let names: Vec<_> = specs.iter().map(|s| s.name).collect();
        assert_eq!(
            names,
            ["gassensor", "kegg", "roadnetwork", "uscensus", "covtype", "mnist"]
        );
        // Dimensional range claim: "wide range of size and dimensionality".
        let dmin = specs.iter().map(|s| s.d).min().unwrap();
        let dmax = specs.iter().map(|s| s.d).max().unwrap();
        assert!(dmin <= 3 && dmax >= 128);
        let nmin = specs.iter().map(|s| s.n).min().unwrap();
        let nmax = specs.iter().map(|s| s.n).max().unwrap();
        assert!(nmin <= 20_000 && nmax >= 400_000);
    }

    #[test]
    fn small_generation_is_valid_and_labelled() {
        // Use shrunken copies of each spec to keep the test fast.
        for mut spec in uci_specs() {
            spec.n = 500;
            let ds = spec.generate(7);
            ds.validate().unwrap();
            let labels = ds.labels.as_ref().unwrap();
            assert_eq!(labels.len(), 500);
            assert!(labels.iter().all(|&l| (l as usize) < spec.modes));
        }
    }

    #[test]
    fn blobs_are_separated() {
        // Points sharing a label must be much closer to each other than the
        // typical cross-label distance.
        let ds = blobs(300, 8, 4, 3);
        let labels = ds.labels.as_ref().unwrap();
        let mut intra = 0.0f64;
        let mut inter = 0.0f64;
        let (mut ni, mut nx) = (0u64, 0u64);
        for i in 0..60 {
            for j in (i + 1)..60 {
                let d2 = sq_dist(ds.points.row(i), ds.points.row(j)) as f64;
                if labels[i] == labels[j] {
                    intra += d2;
                    ni += 1;
                } else {
                    inter += d2;
                    nx += 1;
                }
            }
        }
        if ni > 0 && nx > 0 {
            assert!(inter / nx as f64 > 10.0 * (intra / ni as f64).max(1e-9));
        }
    }

    #[test]
    fn imbalance_skews_mode_sizes() {
        let mut spec = uci_specs().into_iter().find(|s| s.name == "covtype").unwrap();
        spec.n = 2000;
        let ds = spec.generate(11);
        let labels = ds.labels.unwrap();
        let mut counts = vec![0usize; spec.modes];
        for &l in &labels {
            counts[l as usize] += 1;
        }
        let max = *counts.iter().max().unwrap();
        let min = *counts.iter().min().unwrap();
        assert!(max > 4 * min.max(1), "covtype should be imbalanced: {counts:?}");
    }

    #[test]
    fn uniform_has_no_labels() {
        let ds = uniform(100, 5, 3);
        assert!(ds.labels.is_none());
        ds.validate().unwrap();
    }
}
