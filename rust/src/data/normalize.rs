//! Feature normalization.
//!
//! K-means is scale-sensitive and the UCI sets mix wildly different feature
//! ranges; both the paper's CPU baseline and the accelerator operate on
//! normalized data (fixed-point hardware *requires* a bounded range — the
//! Zynq datapath in `hw::pipeline` models Q-format MACs whose calibration
//! assumes inputs in [0, 1] or z-scored ranges).

use crate::data::Dataset;

/// Per-column min-max scaling into [0, 1]. Constant columns map to 0.
pub fn min_max(ds: &mut Dataset) {
    let (n, d) = (ds.n(), ds.d());
    if n == 0 {
        return;
    }
    let mut lo = vec![f32::INFINITY; d];
    let mut hi = vec![f32::NEG_INFINITY; d];
    for row in ds.points.rows_iter() {
        for j in 0..d {
            lo[j] = lo[j].min(row[j]);
            hi[j] = hi[j].max(row[j]);
        }
    }
    let scale: Vec<f32> = (0..d)
        .map(|j| {
            let range = hi[j] - lo[j];
            if range > 0.0 {
                1.0 / range
            } else {
                0.0
            }
        })
        .collect();
    for i in 0..n {
        let row = ds.points.row_mut(i);
        for j in 0..d {
            row[j] = (row[j] - lo[j]) * scale[j];
        }
    }
}

/// Per-column z-score standardization. Constant columns map to 0.
pub fn z_score(ds: &mut Dataset) {
    let (n, d) = (ds.n(), ds.d());
    if n == 0 {
        return;
    }
    let mut mean = vec![0.0f64; d];
    for row in ds.points.rows_iter() {
        for j in 0..d {
            mean[j] += row[j] as f64;
        }
    }
    for m in mean.iter_mut() {
        *m /= n as f64;
    }
    let mut var = vec![0.0f64; d];
    for row in ds.points.rows_iter() {
        for j in 0..d {
            let dlt = row[j] as f64 - mean[j];
            var[j] += dlt * dlt;
        }
    }
    let inv_std: Vec<f32> = var
        .iter()
        .map(|&v| {
            let s = (v / n as f64).sqrt();
            if s > 0.0 {
                (1.0 / s) as f32
            } else {
                0.0
            }
        })
        .collect();
    for i in 0..n {
        let row = ds.points.row_mut(i);
        for j in 0..d {
            row[j] = (row[j] - mean[j] as f32) * inv_std[j];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::util::matrix::Matrix;

    #[test]
    fn min_max_bounds() {
        let mut ds = synth::blobs(500, 6, 3, 1);
        min_max(&mut ds);
        for row in ds.points.rows_iter() {
            for &v in row {
                assert!((0.0..=1.0).contains(&v), "v={v}");
            }
        }
        // Each column must actually reach (close to) both ends.
        for j in 0..ds.d() {
            let col: Vec<f32> = (0..ds.n()).map(|i| ds.points.row(i)[j]).collect();
            let lo = col.iter().cloned().fold(f32::INFINITY, f32::min);
            let hi = col.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            assert!(lo.abs() < 1e-6 && (hi - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    fn z_score_moments() {
        let mut ds = synth::blobs(2000, 4, 3, 2);
        z_score(&mut ds);
        for j in 0..ds.d() {
            let col: Vec<f64> = (0..ds.n()).map(|i| ds.points.row(i)[j] as f64).collect();
            let mean: f64 = col.iter().sum::<f64>() / col.len() as f64;
            let var: f64 =
                col.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / col.len() as f64;
            assert!(mean.abs() < 1e-4, "col {j} mean {mean}");
            assert!((var - 1.0).abs() < 1e-3, "col {j} var {var}");
        }
    }

    #[test]
    fn constant_columns_map_to_zero() {
        let mut ds = crate::data::Dataset::new(
            "const",
            Matrix::from_vec(vec![5.0, 1.0, 5.0, 2.0, 5.0, 3.0], 3, 2).unwrap(),
        );
        let mut ds2 = ds.clone();
        min_max(&mut ds);
        z_score(&mut ds2);
        for i in 0..3 {
            assert_eq!(ds.points.row(i)[0], 0.0);
            assert_eq!(ds2.points.row(i)[0], 0.0);
        }
    }
}
