//! Datasets: the workload substrate.
//!
//! The paper evaluates on "six real-life datasets from the UCI repository
//! … covering a wide range of size and dimensionality". UCI downloads are
//! unavailable in this environment, so [`synth`] provides deterministic
//! generators shaped to the six sets canonically used in triangle-inequality
//! K-means evaluations (see DESIGN.md §3 for the substitution argument:
//! filter effectiveness is governed by n, d, k and cluster separation, all
//! of which the generators reproduce). [`io`] adds a binary on-disk format
//! and a CSV reader so real UCI files can be dropped in when available, and
//! [`normalize`] provides the standard preprocessing.

pub mod io;
pub mod normalize;
pub mod synth;

use crate::error::{Error, Result};
use crate::util::matrix::Matrix;

/// A dataset of `n` points in `d` dimensions.
#[derive(Clone, Debug)]
pub struct Dataset {
    /// Short identifier (`gassensor`, `kegg`, …) used in reports.
    pub name: String,
    /// Row-major points, `n × d`.
    pub points: Matrix,
    /// Ground-truth labels if the generator knows them (synthetic data).
    pub labels: Option<Vec<u32>>,
}

impl Dataset {
    pub fn new(name: impl Into<String>, points: Matrix) -> Self {
        Self { name: name.into(), points, labels: None }
    }

    #[inline]
    pub fn n(&self) -> usize {
        self.points.rows()
    }

    #[inline]
    pub fn d(&self) -> usize {
        self.points.cols()
    }

    /// Validate basic invariants (finite values, non-empty).
    pub fn validate(&self) -> Result<()> {
        if self.n() == 0 || self.d() == 0 {
            return Err(Error::Data(format!(
                "dataset '{}' is empty ({}x{})",
                self.name,
                self.n(),
                self.d()
            )));
        }
        if let Some(bad) = self
            .points
            .as_slice()
            .iter()
            .position(|x| !x.is_finite())
        {
            return Err(Error::Data(format!(
                "dataset '{}' has non-finite value at flat index {bad}",
                self.name
            )));
        }
        if let Some(labels) = &self.labels {
            if labels.len() != self.n() {
                return Err(Error::Data(format!(
                    "dataset '{}' has {} labels for {} points",
                    self.name,
                    labels.len(),
                    self.n()
                )));
            }
        }
        Ok(())
    }

    /// A deterministic subsample (used by benches to bound run time while
    /// preserving the generator's geometry).
    pub fn subsample(&self, max_n: usize, seed: u64) -> Dataset {
        if self.n() <= max_n {
            return self.clone();
        }
        let mut idx: Vec<usize> = (0..self.n()).collect();
        let mut rng = crate::util::rng::Rng::new(seed);
        rng.shuffle(&mut idx);
        idx.truncate(max_n);
        idx.sort_unstable();
        let points = self.points.gather_rows(&idx);
        let labels = self
            .labels
            .as_ref()
            .map(|l| idx.iter().map(|&i| l[i]).collect());
        Dataset {
            name: format!("{}@{}", self.name, max_n),
            points,
            labels,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Dataset {
        let m = Matrix::from_vec(vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0], 3, 2).unwrap();
        Dataset::new("tiny", m)
    }

    #[test]
    fn validate_accepts_good_data() {
        tiny().validate().unwrap();
    }

    #[test]
    fn validate_rejects_nan() {
        let mut ds = tiny();
        ds.points.row_mut(1)[0] = f32::NAN;
        assert!(ds.validate().is_err());
    }

    #[test]
    fn validate_rejects_label_mismatch() {
        let mut ds = tiny();
        ds.labels = Some(vec![0, 1]);
        assert!(ds.validate().is_err());
    }

    #[test]
    fn subsample_preserves_rows() {
        let ds = synth::blobs(100, 4, 3, 7);
        let sub = ds.subsample(10, 1);
        assert_eq!(sub.n(), 10);
        assert_eq!(sub.d(), 4);
        // Every subsampled row must exist in the original.
        for r in 0..sub.n() {
            let row = sub.points.row(r);
            assert!(
                (0..ds.n()).any(|i| ds.points.row(i) == row),
                "row {r} not found in original"
            );
        }
        // Deterministic.
        let sub2 = ds.subsample(10, 1);
        assert_eq!(sub.points, sub2.points);
    }

    #[test]
    fn subsample_noop_when_small() {
        let ds = tiny();
        let sub = ds.subsample(10, 0);
        assert_eq!(sub.n(), 3);
        assert_eq!(sub.name, "tiny");
    }
}
