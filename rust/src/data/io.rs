//! Dataset I/O: a binary matrix format and a CSV reader.
//!
//! The binary format (`.kpm`, "KPynq matrix") is a tiny self-describing
//! little-endian container:
//!
//! ```text
//! magic  "KPM1"          4 bytes
//! rows   u64 LE          8 bytes
//! cols   u64 LE          8 bytes
//! data   rows*cols f32   little-endian row-major
//! ```
//!
//! Generating the large UCI-equivalents takes a couple of seconds each;
//! examples cache them with [`save`]/[`load`] so repeated bench runs are
//! instant. [`read_csv`] lets a real UCI CSV be substituted for a generator
//! when the file is available.

use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::path::Path;

use crate::data::Dataset;
use crate::error::{Error, Result};
use crate::util::matrix::Matrix;

const MAGIC: &[u8; 4] = b"KPM1";

/// Write a dataset's points to the binary format (labels are not stored).
pub fn save(ds: &Dataset, path: &Path) -> Result<()> {
    let mut w = BufWriter::new(File::create(path)?);
    w.write_all(MAGIC)?;
    w.write_all(&(ds.n() as u64).to_le_bytes())?;
    w.write_all(&(ds.d() as u64).to_le_bytes())?;
    // Bulk-convert rows to LE bytes. f32::to_le_bytes per element is the
    // portable route; the buffer writer amortises the syscalls.
    let mut buf = Vec::with_capacity(ds.d() * 4);
    for row in ds.points.rows_iter() {
        buf.clear();
        for v in row {
            buf.extend_from_slice(&v.to_le_bytes());
        }
        w.write_all(&buf)?;
    }
    w.flush()?;
    Ok(())
}

/// Load a dataset from the binary format.
pub fn load(name: &str, path: &Path) -> Result<Dataset> {
    let mut r = BufReader::new(File::open(path)?);
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(Error::Data(format!(
            "{}: bad magic {:?} (not a KPM1 file)",
            path.display(),
            magic
        )));
    }
    let mut u64buf = [0u8; 8];
    r.read_exact(&mut u64buf)?;
    let rows = u64::from_le_bytes(u64buf) as usize;
    r.read_exact(&mut u64buf)?;
    let cols = u64::from_le_bytes(u64buf) as usize;
    let total = rows
        .checked_mul(cols)
        .ok_or_else(|| Error::Data("matrix size overflow".into()))?;
    let mut bytes = vec![0u8; total * 4];
    r.read_exact(&mut bytes)?;
    let mut data = Vec::with_capacity(total);
    for chunk in bytes.chunks_exact(4) {
        data.push(f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]));
    }
    let ds = Dataset::new(name, Matrix::from_vec(data, rows, cols)?);
    ds.validate()?;
    Ok(ds)
}

/// Load-or-generate cache helper used by examples and benches.
pub fn load_or_generate<F>(name: &str, cache_dir: &Path, gen: F) -> Result<Dataset>
where
    F: FnOnce() -> Dataset,
{
    let path = cache_dir.join(format!("{name}.kpm"));
    if path.exists() {
        if let Ok(ds) = load(name, &path) {
            return Ok(ds);
        }
        // Corrupt cache: fall through and regenerate.
    }
    let ds = gen();
    std::fs::create_dir_all(cache_dir)?;
    save(&ds, &path)?;
    Ok(ds)
}

/// Read a numeric CSV (no header handling beyond `skip_header`, `,`
/// delimiter, non-numeric columns rejected). Rows of inconsistent arity
/// are an error — silent row-dropping hides data bugs.
pub fn read_csv(name: &str, path: &Path, skip_header: bool) -> Result<Dataset> {
    let r = BufReader::new(File::open(path)?);
    let mut data: Vec<f32> = Vec::new();
    let mut cols = None;
    let mut rows = 0usize;
    for (i, line) in r.lines().enumerate() {
        let line = line?;
        if i == 0 && skip_header {
            continue;
        }
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        let fields: Vec<&str> = trimmed.split(',').collect();
        match cols {
            None => cols = Some(fields.len()),
            Some(c) if c != fields.len() => {
                return Err(Error::Data(format!(
                    "{}: row {} has {} fields, expected {}",
                    path.display(),
                    i + 1,
                    fields.len(),
                    c
                )));
            }
            _ => {}
        }
        for f in fields {
            let v: f32 = f.trim().parse().map_err(|_| {
                Error::Data(format!(
                    "{}: row {}: non-numeric field '{}'",
                    path.display(),
                    i + 1,
                    f
                ))
            })?;
            data.push(v);
        }
        rows += 1;
    }
    let cols = cols.ok_or_else(|| Error::Data(format!("{}: empty csv", path.display())))?;
    let ds = Dataset::new(name, Matrix::from_vec(data, rows, cols)?);
    ds.validate()?;
    Ok(ds)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    fn tmpdir() -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "kpynq-io-test-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn save_load_roundtrip() {
        let dir = tmpdir();
        let ds = synth::blobs(200, 7, 3, 5);
        let path = dir.join("roundtrip.kpm");
        save(&ds, &path).unwrap();
        let back = load("blobs", &path).unwrap();
        assert_eq!(back.points, ds.points);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn load_rejects_bad_magic() {
        let dir = tmpdir();
        let path = dir.join("bad.kpm");
        std::fs::write(&path, b"NOPEaaaaaaaaaaaaaaaa").unwrap();
        assert!(load("x", &path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn load_or_generate_caches() {
        let dir = tmpdir();
        let mut calls = 0;
        let a = load_or_generate("cachetest", &dir, || {
            calls += 1;
            synth::blobs(50, 3, 2, 9)
        })
        .unwrap();
        let b = load_or_generate("cachetest", &dir, || {
            calls += 1;
            synth::blobs(50, 3, 2, 9)
        })
        .unwrap();
        assert_eq!(calls, 1, "second call must hit the cache");
        assert_eq!(a.points, b.points);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn csv_reads_and_validates() {
        let dir = tmpdir();
        let path = dir.join("data.csv");
        std::fs::write(&path, "a,b\n1.0,2.0\n3.5,-4\n").unwrap();
        let ds = read_csv("csv", &path, true).unwrap();
        assert_eq!((ds.n(), ds.d()), (2, 2));
        assert_eq!(ds.points.row(1), &[3.5, -4.0]);

        std::fs::write(&path, "1,2\n3\n").unwrap();
        assert!(read_csv("csv", &path, false).is_err(), "ragged rows rejected");

        std::fs::write(&path, "1,x\n").unwrap();
        assert!(read_csv("csv", &path, false).is_err(), "non-numeric rejected");
        std::fs::remove_dir_all(&dir).ok();
    }
}
