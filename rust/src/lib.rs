//! # KPynq — work-efficient triangle-inequality K-means, reproduced in full
//!
//! This crate reproduces *KPynq: A Work-Efficient Triangle-Inequality based
//! K-means on FPGA* (Wang, Zeng, Feng, Deng, Ding — CS.DC 2019) as a
//! three-layer Rust + JAX + Pallas system:
//!
//! * **Layer 3 (this crate)** — the KPynq *system*: the multi-level-filter
//!   K-means algorithm family ([`kmeans`]), a cycle-approximate model of the
//!   Pynq-Z1's Zynq XC7Z020 programmable logic ([`hw`]) including the DMA /
//!   AXIS transport, BRAM banking, the pipelined distance calculator and the
//!   point/group filter units, the host-side coordinator ([`coordinator`])
//!   that tiles datasets, drives double-buffered transfers and manages run
//!   state, the multi-tenant serving layer ([`serve`]) that queues,
//!   shards and micro-batches concurrent fit requests over the coordinator —
//!   one-shot from NDJSON streams, or as a persistent socket daemon
//!   (`kpynq serve --listen`, wire protocol normative in PROTOCOL.md) —
//!   and the cross-process shard supervisor ([`cluster`]) that puts N such
//!   daemons behind one endpoint (`kpynq cluster`) with BatchKey-affine
//!   fan-out, crash recovery and exactly-once fan-in — supervised local
//!   children, or already-running daemons on other hosts
//!   (`kpynq cluster --remote`, multi-host mode).
//! * **Layer 2** — JAX compute graphs (`python/compile/model.py`), AOT-lowered
//!   to HLO text and executed from Rust through PJRT ([`runtime`]). Python is
//!   never on the request path.
//! * **Layer 1** — Pallas kernels (`python/compile/kernels/`) for the distance
//!   calculator hot-spot, re-thought for TPU (MXU matmul-form distances,
//!   VMEM-resident centroid bank) per DESIGN.md §Hardware-Adaptation.
//!
//! The original evaluation ran on a Pynq-Z1 board; this environment has no
//! FPGA, so the hardware is *simulated* — functionally bit-exact, with timing
//! and energy derived from a calibrated cycle model (DESIGN.md §1 documents
//! every substitution). The benches under `rust/benches/` regenerate each of
//! the paper's reported results; `examples/uci_clustering.rs` is the
//! end-to-end driver.
//!
//! ## Quickstart
//!
//! ```no_run
//! use kpynq::data::synth;
//! use kpynq::kmeans::KMeansConfig;
//! use kpynq::coordinator::{KpynqSystem, SystemConfig};
//!
//! let ds = synth::blobs(10_000, 16, 8, 0xC0FFEE);
//! let sys = KpynqSystem::new(SystemConfig::default()).unwrap();
//! let out = sys.cluster(&ds, &KMeansConfig { k: 8, ..Default::default() }).unwrap();
//! println!("inertia {:.3} in {} iters, {} cycles simulated",
//!          out.fit.inertia, out.fit.iterations, out.report.total_cycles);
//! ```

pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod error;
pub mod harness;
pub mod hw;
pub mod kmeans;
pub mod obs;
pub mod runtime;
pub mod serve;
pub mod util;

pub use error::{Error, Result};
