//! Cycle-approximate model of the KPynq hardware (DESIGN.md §1).
//!
//! The paper deploys on a Pynq-Z1 (Zynq XC7Z020: ARM Cortex-A9 PS + Artix-7
//! PL). No FPGA exists in this environment, so this module *is* the board:
//!
//! * [`zynq`] — the part: resource counts, clocks, AXI port widths.
//! * [`bram`] — on-chip BRAM banking and capacity accounting.
//! * [`dma`] — the DMA controller + AXIS stream timing model.
//! * [`pipeline`] — the pipelined, lane-parallel Distance Calculator.
//! * [`filter_unit`] — the Multi-level Filter stage (point + group level).
//! * [`accelerator`] — the composed PL core: functional execution is
//!   delegated to `kmeans::yinyang::step_point` (identical decisions to the
//!   software algorithm, by construction) while the timing model charges
//!   cycles to DMA / filter / pipeline / PS-update per the configuration.
//! * [`resource`] — LUT/FF/DSP/BRAM estimator: which configurations fit.
//! * [`energy`] — power/energy model calibrated to the paper's
//!   energy-efficiency ratio structure.
//! * [`cpu_model`] — the CPU baseline's analytic timing model, so CPU and
//!   FPGA are compared in one consistent currency (see DESIGN.md §1, the
//!   substitution table, for why measured host wall-clock is *not* used).
//! * [`fixed_point`] — Q-format quantisation analysis for the datapath.

pub mod accelerator;
pub mod bram;
pub mod cpu_model;
pub mod dma;
pub mod energy;
pub mod filter_unit;
pub mod fixed_point;
pub mod pipeline;
pub mod resource;
pub mod zynq;

pub use accelerator::{AccelConfig, Accelerator, CycleBreakdown, IterOutcome};
pub use zynq::ZynqPart;
