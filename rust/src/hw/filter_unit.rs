//! The Multi-level Filter stage, as hardware.
//!
//! In the PL the filter sits between the AXIS input and the distance
//! pipeline: each point's bounds are read from the bound BRAM, the global
//! test is `G` parallel comparators plus a min-tree (one point per cycle
//! for G up to [`FilterUnitConfig::max_parallel_groups`]), and survivors
//! issue group scans to the pipeline. Bound updates on the way out cost
//! one write slot per point.
//!
//! The unit is *timing-only* — functional decisions come from
//! `kmeans::yinyang::step_point` — but its comparator count shows up in
//! the LUT budget (`resource::estimate`) and its throughput in the cycle
//! model.

/// Configuration of the filter stage.
#[derive(Clone, Copy, Debug)]
pub struct FilterUnitConfig {
    /// Comparators instantiated for the group min-tree: the global test
    /// processes min(G, this) bounds per cycle.
    pub max_parallel_groups: u64,
}

impl Default for FilterUnitConfig {
    fn default() -> Self {
        Self { max_parallel_groups: 16 }
    }
}

impl FilterUnitConfig {
    /// Cycles for the global-filter test of one point with `g` groups:
    /// ceil(g / parallel) comparator waves + 1 commit cycle.
    pub fn global_test_cycles(&self, g: usize) -> u64 {
        (g as u64).div_ceil(self.max_parallel_groups) + 1
    }

    /// Cycles to apply drift updates to one point's bounds (1 + g values,
    /// four per cycle: two true-dual-port BRAMs banked over the bound
    /// tile, each feeding an add lane per port).
    pub fn drift_update_cycles(&self, g: usize) -> u64 {
        (1 + g as u64).div_ceil(4)
    }

    /// Cycles to write back one point's updated bounds + assignment.
    pub fn writeback_cycles(&self, g: usize) -> u64 {
        // assignment + ub in one beat, bounds four per cycle (same banks).
        1.max((1 + g as u64).div_ceil(4))
    }

    /// LUTs for the comparator bank + min tree (16-bit compare ≈ 16 LUTs,
    /// min-tree mux ≈ 24 LUTs per node).
    pub fn luts(&self) -> u64 {
        self.max_parallel_groups * 16 + self.max_parallel_groups.saturating_sub(1) * 24
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_wave_for_small_g() {
        let f = FilterUnitConfig::default();
        assert_eq!(f.global_test_cycles(1), 2);
        assert_eq!(f.global_test_cycles(16), 2);
        assert_eq!(f.global_test_cycles(17), 3);
        assert_eq!(f.global_test_cycles(32), 3);
    }

    #[test]
    fn update_and_writeback_scale_with_groups() {
        let f = FilterUnitConfig::default();
        assert_eq!(f.drift_update_cycles(1), 1);
        assert_eq!(f.drift_update_cycles(8), 3); // ceil(9/4)
        assert_eq!(f.writeback_cycles(8), 3);
        assert_eq!(f.writeback_cycles(1), 1);
    }

    #[test]
    fn luts_grow_with_parallelism() {
        let small = FilterUnitConfig { max_parallel_groups: 4 }.luts();
        let big = FilterUnitConfig { max_parallel_groups: 16 }.luts();
        assert!(big > small);
    }
}
