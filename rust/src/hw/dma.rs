//! DMA controller + AXIS stream timing model.
//!
//! The paper: "a DMA controller and a high-performance AXIS streaming
//! interface build the data connection between PS and PL", with the Python
//! program in PS initiating transfers. The model charges:
//!
//! * a fixed per-transfer setup cost (descriptor write + interrupt path,
//!   paid on the PS but expressed in PL cycles);
//! * per-burst overhead on the AXI HP port;
//! * streaming cycles at `min(port width × PL clock, DDR share)`.
//!
//! Multiple in-flight streams (points in, bounds in, results out) share the
//! DDR bandwidth ceiling; [`DmaModel::concurrent`] computes the makespan of
//! a set of parallel transfers under that ceiling — used by the coordinator
//! when double-buffering tiles.

use super::zynq::ZynqPart;

/// One direction of a DMA transfer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dir {
    /// DDR → PL (points, centroids, bounds in).
    ToPl,
    /// PL → DDR (assignments, bounds out, accumulators).
    FromPl,
}

/// A requested transfer.
#[derive(Clone, Copy, Debug)]
pub struct Transfer {
    pub bytes: u64,
    pub dir: Dir,
}

/// Timing parameters of the AXI DMA engine.
#[derive(Clone, Debug)]
pub struct DmaModel {
    /// Port payload per PL cycle (bytes) — AXI HP is 64-bit on Zynq-7000.
    pub port_bytes_per_cycle: u64,
    /// Burst length in beats (AXI4 INCR bursts, 256 max; 64 typical).
    pub burst_beats: u64,
    /// Dead cycles between bursts (address phase + handshake).
    pub inter_burst_gap: u64,
    /// Fixed setup cost per transfer, in PL cycles. AXI DMA in
    /// scatter-gather mode prefetches descriptor chains, so the steady-
    /// state per-tile cost is the descriptor fetch + channel turnaround
    /// (~0.4 µs ≈ 40 PL cycles at 100 MHz), not a full PS interrupt round
    /// trip.
    pub setup_cycles: u64,
    /// Shared DDR bandwidth ceiling, bytes per second.
    pub ddr_bandwidth: f64,
    /// PL clock, needed to convert the DDR ceiling into per-cycle budget.
    pub pl_clock_hz: f64,
}

impl DmaModel {
    pub fn for_part(part: &ZynqPart) -> Self {
        Self {
            port_bytes_per_cycle: part.axi_hp_bytes,
            burst_beats: 64,
            inter_burst_gap: 4,
            setup_cycles: 40,
            ddr_bandwidth: part.ddr_bandwidth,
            pl_clock_hz: part.pl_clock_hz,
        }
    }

    /// PL cycles for one transfer on an otherwise idle port.
    pub fn transfer_cycles(&self, t: Transfer) -> u64 {
        if t.bytes == 0 {
            return 0;
        }
        let beats = t.bytes.div_ceil(self.port_bytes_per_cycle);
        let bursts = beats.div_ceil(self.burst_beats);
        let stream = beats + bursts.saturating_sub(1) * self.inter_burst_gap;
        // DDR ceiling: the port cannot stream faster than its DDR share.
        let ddr_bytes_per_cycle = self.ddr_bandwidth / self.pl_clock_hz;
        let ddr_cycles = (t.bytes as f64 / ddr_bytes_per_cycle).ceil() as u64;
        self.setup_cycles + stream.max(ddr_cycles)
    }

    /// Makespan (PL cycles) of transfers running concurrently on separate
    /// HP ports but sharing DDR bandwidth: each transfer takes at least its
    /// solo time, and the set takes at least total-bytes / DDR-rate.
    pub fn concurrent(&self, transfers: &[Transfer]) -> u64 {
        if transfers.is_empty() {
            return 0;
        }
        let solo_max = transfers
            .iter()
            .map(|&t| self.transfer_cycles(t))
            .max()
            .unwrap_or(0);
        let total_bytes: u64 = transfers.iter().map(|t| t.bytes).sum();
        let ddr_bytes_per_cycle = self.ddr_bandwidth / self.pl_clock_hz;
        let ddr_floor = (total_bytes as f64 / ddr_bytes_per_cycle).ceil() as u64
            + self.setup_cycles;
        solo_max.max(ddr_floor)
    }

    /// Effective bandwidth (bytes/s) achieved by one transfer of `bytes`.
    pub fn effective_bandwidth(&self, bytes: u64) -> f64 {
        if bytes == 0 {
            return 0.0;
        }
        let cycles = self.transfer_cycles(Transfer { bytes, dir: Dir::ToPl });
        bytes as f64 / (cycles as f64 / self.pl_clock_hz)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> DmaModel {
        DmaModel::for_part(&ZynqPart::xc7z020())
    }

    #[test]
    fn zero_bytes_is_free() {
        assert_eq!(model().transfer_cycles(Transfer { bytes: 0, dir: Dir::ToPl }), 0);
    }

    #[test]
    fn small_transfer_dominated_by_setup() {
        let m = model();
        let c = m.transfer_cycles(Transfer { bytes: 64, dir: Dir::ToPl });
        // 64 B = 8 beats, one burst → setup + 8.
        assert_eq!(c, m.setup_cycles + 8);
    }

    #[test]
    fn cycles_conserve_bytes() {
        // Streaming cycles must never be fewer than bytes / port width —
        // the link physically cannot move more than 8 B/cycle.
        let m = model();
        for bytes in [1u64, 100, 4096, 1 << 20, 10 << 20] {
            let c = m.transfer_cycles(Transfer { bytes, dir: Dir::ToPl });
            assert!(
                c >= bytes.div_ceil(m.port_bytes_per_cycle),
                "bytes {bytes} took only {c} cycles"
            );
        }
    }

    #[test]
    fn large_transfer_is_port_limited() {
        // A single HP port moves 8 B/cycle at 100 MHz = 800 MB/s; a big
        // transfer must approach (but never exceed) that, far below the
        // DDR ceiling — which only binds for concurrent transfers.
        let m = model();
        let bytes = 64u64 << 20; // 64 MB
        let bw = m.effective_bandwidth(bytes);
        let port_rate = m.port_bytes_per_cycle as f64 * m.pl_clock_hz;
        assert!(bw <= port_rate * 1.01, "bw {bw} exceeds the port");
        assert!(bw > port_rate * 0.85, "bw {bw} too low for a large burst");
        assert!(bw < m.ddr_bandwidth);
    }

    #[test]
    fn concurrent_is_bounded_by_parts() {
        let m = model();
        let a = Transfer { bytes: 1 << 20, dir: Dir::ToPl };
        let b = Transfer { bytes: 1 << 18, dir: Dir::FromPl };
        let mk = m.concurrent(&[a, b]);
        // At least as long as the longest member…
        assert!(mk >= m.transfer_cycles(a));
        // …and no longer than running them back-to-back.
        assert!(mk <= m.transfer_cycles(a) + m.transfer_cycles(b));
    }

    #[test]
    fn concurrent_respects_ddr_floor() {
        let m = model();
        // Many large parallel transfers: makespan must respect total bytes
        // over DDR bandwidth.
        let ts: Vec<Transfer> =
            (0..4).map(|_| Transfer { bytes: 8 << 20, dir: Dir::ToPl }).collect();
        let mk = m.concurrent(&ts);
        let ddr_bytes_per_cycle = m.ddr_bandwidth / m.pl_clock_hz;
        let floor = ((32 << 20) as f64 / ddr_bytes_per_cycle) as u64;
        assert!(mk >= floor);
    }
}
