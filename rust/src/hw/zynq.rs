//! The Zynq-7000 part and board description.
//!
//! Numbers from the paper's §II and the Pynq-Z1 reference manual: ZYNQ
//! XC7Z020-1CLG400C — 13,300 logic slices (4 six-input LUTs + 8 FFs each),
//! 630 KB BRAM (280 × BRAM_18K), 220 DSP48E1 slices, dual Cortex-A9 at
//! 650 MHz. PL fabric clock for this class of design: 100–142 MHz; KPynq's
//! default is 100 MHz.

/// Static resource and clock description of a Zynq part + board.
#[derive(Clone, Debug)]
pub struct ZynqPart {
    pub name: &'static str,
    /// 6-input LUTs (13,300 slices × 4).
    pub luts: u64,
    /// Flip-flops (13,300 slices × 8).
    pub ffs: u64,
    /// BRAM in 18 Kb blocks (280 on the 7020 = 630 KB).
    pub bram_18k: u64,
    /// DSP48E1 slices.
    pub dsp: u64,
    /// PL fabric clock (Hz).
    pub pl_clock_hz: f64,
    /// PS (ARM) clock (Hz).
    pub ps_clock_hz: f64,
    /// AXI HP port data width in bytes (64-bit on Zynq-7000).
    pub axi_hp_bytes: u64,
    /// Number of AXI HP ports usable by DMA masters.
    pub axi_hp_ports: u64,
    /// Effective DDR bandwidth ceiling shared by all ports (bytes/s).
    /// DDR3-1050 x32 on Pynq-Z1 peaks at 4.2 GB/s; ~60% achievable.
    pub ddr_bandwidth: f64,
}

impl ZynqPart {
    /// The Pynq-Z1's XC7Z020, as used in the paper.
    pub fn xc7z020() -> Self {
        Self {
            name: "XC7Z020-1CLG400C",
            luts: 53_200,
            ffs: 106_400,
            bram_18k: 280,
            dsp: 220,
            pl_clock_hz: 100.0e6,
            ps_clock_hz: 650.0e6,
            axi_hp_bytes: 8,
            axi_hp_ports: 4,
            ddr_bandwidth: 2.5e9,
        }
    }

    /// A larger part (ZU7EV-class) used by the design-space example to
    /// demonstrate the "various FPGAs" configurability claim.
    pub fn zu7ev() -> Self {
        Self {
            name: "XCZU7EV",
            luts: 230_400,
            ffs: 460_800,
            bram_18k: 624,
            dsp: 1_728,
            pl_clock_hz: 300.0e6,
            ps_clock_hz: 1_200.0e6,
            axi_hp_bytes: 16,
            axi_hp_ports: 6,
            ddr_bandwidth: 10.0e9,
        }
    }

    /// BRAM capacity in bytes (18 Kb blocks × 18,432 bits, data bits only:
    /// 16 Kb data + 2 Kb parity; we count the 2 KB data payload per block
    /// — 280 × 2.25 KB = 630 KB matches the paper's figure).
    pub fn bram_bytes(&self) -> u64 {
        self.bram_18k * 2304
    }

    /// Seconds for `cycles` PL cycles.
    pub fn pl_seconds(&self, cycles: u64) -> f64 {
        cycles as f64 / self.pl_clock_hz
    }

    /// PL cycles for a duration (rounded up).
    pub fn pl_cycles(&self, seconds: f64) -> u64 {
        (seconds * self.pl_clock_hz).ceil() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn xc7z020_matches_paper_numbers() {
        let p = ZynqPart::xc7z020();
        // §II: "13,300 logic slices, each with four 6-input LUTs and 8
        // flip-flops, 630 KB BRAM (280 BRAM_18K), and 220 DSP slices".
        assert_eq!(p.luts, 13_300 * 4);
        assert_eq!(p.ffs, 13_300 * 8);
        assert_eq!(p.bram_18k, 280);
        assert_eq!(p.dsp, 220);
        assert_eq!(p.bram_bytes(), 630 * 1024);
        assert_eq!(p.ps_clock_hz, 650.0e6);
    }

    #[test]
    fn cycle_time_roundtrip() {
        let p = ZynqPart::xc7z020();
        assert_eq!(p.pl_seconds(100_000_000), 1.0);
        assert_eq!(p.pl_cycles(0.5), 50_000_000);
    }

    #[test]
    fn zu7ev_is_strictly_bigger() {
        let small = ZynqPart::xc7z020();
        let big = ZynqPart::zu7ev();
        assert!(big.luts > small.luts && big.dsp > small.dsp && big.bram_18k > small.bram_18k);
    }
}
