//! Analytic timing model of the CPU baseline.
//!
//! Why a model and not host wall-clock: the simulation's FPGA side produces
//! *Zynq* cycle counts, and comparing those against wall-clock on this
//! machine's (much newer) CPU would make the speedup an artifact of the
//! host. Instead both sides are expressed in the same currency — seconds
//! derived from an explicit machine model — which is also how the paper's
//! own evaluation should be read (their baseline hardware is fixed).
//!
//! The baseline is the paper's "optimized CPU-based standard K-means": a
//! single-threaded, `-O3`-compiled Lloyd on a desktop-class core (the
//! paper's implied ~95 W package — see `energy.rs` — rules out the on-board
//! ARM). Calibration:
//!
//! * 3.4 GHz with SSE-class auto-vectorisation: 4 f32 MACs/cycle peak,
//!   sustained efficiency 0.25 → ~3.4 GMAC/s. This is the measured class
//!   of straightforward single-threaded K-means distance loops (argmin
//!   dependency chain + strided centroid reads); hand-blocked AVX2 GEMM
//!   formulations go far higher, but that is not the baseline the paper
//!   (or any 2019 K-means acceleration paper) compares against.
//! * A fixed per-distance overhead (loop control, argmin compare-and-
//!   select ≈ 2 ns) that dominates for low-d datasets — why FPGA wins
//!   shrink on roadnetwork-like data.
//! * The assignment step reads every point every iteration: a bandwidth
//!   floor of n·d·4 bytes / 20 GB/s effective.
//!
//! With these defaults the CPU sustains ~3.4 GMAC/s, against the 7020
//! accelerator's 6.4 GMAC/s peak at the default P=8×W=8. Raw rates are
//! comparable; KPynq's margin comes from the multi-level filter doing a
//! fraction of the work — exactly the paper's "work-efficient" story (§I).
//! The resulting speedup band (≈1× on d=3 up to ≈4× on d=128) matches the
//! paper's avg 2.95× / max 4.2× shape; EXPERIMENTS.md §Calibration records
//! the sensitivity of the table to these constants.

/// CPU baseline parameters.
#[derive(Clone, Debug)]
pub struct CpuModel {
    pub clock_hz: f64,
    /// Peak f32 MACs per cycle (vector width × FMA ports).
    pub macs_per_cycle: f64,
    /// Sustained fraction of peak for the distance kernel.
    pub efficiency: f64,
    /// Fixed cost per point↔centroid distance (loop + argmin), seconds.
    pub per_distance_overhead_s: f64,
    /// Effective streaming bandwidth (bytes/s) for the n·d point sweep.
    pub mem_bandwidth: f64,
    /// Fixed per-iteration overhead (loop setup, reduction), seconds.
    pub iter_overhead_s: f64,
}

impl Default for CpuModel {
    fn default() -> Self {
        Self {
            clock_hz: 3.4e9,
            macs_per_cycle: 4.0,
            efficiency: 0.25,
            per_distance_overhead_s: 2.0e-9,
            mem_bandwidth: 20.0e9,
            iter_overhead_s: 2.0e-6,
        }
    }
}

impl CpuModel {
    /// Sustained MACs per second.
    pub fn sustained_macs(&self) -> f64 {
        self.clock_hz * self.macs_per_cycle * self.efficiency
    }

    /// Seconds for one standard-K-means iteration (assignment + update).
    pub fn iteration_seconds(&self, n: usize, k: usize, d: usize) -> f64 {
        let n_dists = (n as f64) * (k as f64);
        let assign_macs = n_dists * (d as f64);
        let compute =
            assign_macs / self.sustained_macs() + n_dists * self.per_distance_overhead_s;
        let memory = (n as f64) * (d as f64) * 4.0 / self.mem_bandwidth;
        // Assignment is the max of its compute and memory costs (they
        // overlap on an OoO core); update adds an n·d pass.
        let update = (n as f64) * (d as f64) / self.sustained_macs()
            + (n as f64) * (d as f64) * 4.0 / self.mem_bandwidth;
        compute.max(memory) + update + self.iter_overhead_s
    }

    /// Seconds for a whole standard-K-means run.
    pub fn run_seconds(&self, n: usize, k: usize, d: usize, iterations: usize) -> f64 {
        self.iteration_seconds(n, k, d) * iterations as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sustained_rate_is_sane() {
        let m = CpuModel::default();
        let g = m.sustained_macs() / 1e9;
        assert!((2.0..6.0).contains(&g), "sustained {g} GMAC/s");
    }

    #[test]
    fn low_d_is_overhead_dominated() {
        // At d=3 the per-distance overhead must contribute more than the
        // MAC work — the reason low-d datasets favour the CPU less/more
        // evenly (see module docs).
        let m = CpuModel::default();
        let overhead = m.per_distance_overhead_s;
        let macs = 3.0 / m.sustained_macs();
        assert!(overhead > macs, "{overhead} vs {macs}");
    }

    #[test]
    fn compute_bound_for_large_k_memory_bound_for_k1() {
        let m = CpuModel::default();
        // k=64: assignment compute dominates the memory sweep.
        let t64 = m.iteration_seconds(100_000, 64, 32);
        let macs = 100_000.0 * 64.0 * 32.0;
        assert!(t64 >= macs / m.sustained_macs());
        // k=1: memory floor dominates; time must exceed the sweep cost.
        let t1 = m.iteration_seconds(1_000_000, 1, 8);
        assert!(t1 >= 1_000_000.0 * 8.0 * 4.0 / m.mem_bandwidth);
    }

    #[test]
    fn scales_linearly_in_iterations() {
        let m = CpuModel::default();
        let one = m.run_seconds(10_000, 16, 32, 1);
        let ten = m.run_seconds(10_000, 16, 32, 10);
        assert!((ten / one - 10.0).abs() < 1e-9);
    }
}
