//! The composed KPynq PL accelerator: Multi-level Filters + Distance
//! Calculator + DMA streaming, executed functionally and timed cycle-
//! approximately.
//!
//! Functional path: every filter decision and every distance comes from
//! `kmeans::yinyang::step_point` — the same function the software
//! algorithm runs — so the accelerator's clustering output is identical to
//! the software's *by construction* (asserted by the `hw_equivalence`
//! integration tests). What this module adds is the **timing**: each
//! iteration is split into streamed tiles; each tile charges
//!
//! * DMA-in (points + bounds + assignments),
//! * the filter stage (drift update, global test, writeback),
//! * the distance pipeline (only the work the filter let through),
//! * DMA-out (updated bounds + assignments),
//!
//! with double buffering overlapping a tile's DMA against the previous
//! tile's compute, exactly as the BRAM budget provisions (`resource`).
//! The PS contributes the centroid update (divisions + drift) and transfer
//! setup, converted to PL-clock cycles so the report has one currency.

use crate::data::Dataset;
use crate::error::Result;
use crate::kmeans::bounds::group_max_drifts;
use crate::kmeans::kernel::scan_all;
use crate::kmeans::yinyang::{group_centroids, step_point, FilterState};
use crate::kmeans::{
    centroid_drifts, compute_inertia, metrics::IterStats, recompute_centroids, FitResult,
    KMeansConfig, RunStats,
};
use crate::util::matrix::Matrix;

use super::dma::{Dir, DmaModel, Transfer};
use super::energy::PowerModel;
use super::filter_unit::FilterUnitConfig;
use super::pipeline::PipelineConfig;
use super::resource::{self, ProblemShape, ResourceEstimate, BOUND_BYTES, FEATURE_BYTES};
use super::zynq::ZynqPart;

/// Full accelerator configuration.
#[derive(Clone, Debug)]
pub struct AccelConfig {
    pub pipeline: PipelineConfig,
    pub filter: FilterUnitConfig,
    /// Streaming tile size (points per DMA burst / BRAM tile).
    pub tile_points: usize,
    /// Disable the multi-level filter (ablation: hardware standard K-means).
    pub enable_filters: bool,
    pub part: ZynqPart,
    pub power: PowerModel,
}

impl Default for AccelConfig {
    /// The paper's design point: P=8 lanes × 8-wide MAC trees = 74 DSPs of
    /// the 220, leaving headroom for the filter/bound arithmetic, with the
    /// distance pipeline (not the AXIS link) as the unfiltered bottleneck —
    /// the regime where the multi-level filter buys wall-clock.
    /// `fig_parallelism_sweep` explores the rest of the space.
    fn default() -> Self {
        Self {
            pipeline: PipelineConfig { lanes: 8, mac_width: 8 },
            filter: FilterUnitConfig::default(),
            tile_points: 256,
            enable_filters: true,
            part: ZynqPart::xc7z020(),
            power: PowerModel::default(),
        }
    }
}

/// Cycle breakdown of one iteration (PL cycles; PS work converted).
#[derive(Clone, Copy, Debug, Default)]
pub struct CycleBreakdown {
    pub dma_in: u64,
    pub dma_out: u64,
    pub filter: u64,
    pub pipeline: u64,
    pub ps_update: u64,
    /// Makespan after double-buffer overlap (≤ sum of the parts).
    pub total: u64,
}

impl CycleBreakdown {
    pub fn serial_sum(&self) -> u64 {
        self.dma_in + self.dma_out + self.filter + self.pipeline + self.ps_update
    }
}

/// One iteration's outcome: work stats + cycles.
#[derive(Clone, Debug)]
pub struct IterOutcome {
    pub stats: IterStats,
    pub cycles: CycleBreakdown,
}

/// Whole accelerated run.
#[derive(Clone, Debug)]
pub struct AccelRunResult {
    pub fit: FitResult,
    pub iters: Vec<CycleBreakdown>,
    pub total_cycles: u64,
    pub seconds: f64,
    /// Fraction of total cycles the distance pipeline was busy — feeds the
    /// dynamic-power term of the energy model.
    pub pipeline_utilization: f64,
    pub dma_bytes: u64,
    pub resources: ResourceEstimate,
}

/// The accelerator instance.
pub struct Accelerator {
    pub cfg: AccelConfig,
    dma: DmaModel,
}

impl Accelerator {
    pub fn new(cfg: AccelConfig) -> Self {
        let dma = DmaModel::for_part(&cfg.part);
        Self { cfg, dma }
    }

    /// Resource estimate for a problem shape; errors if it does not fit.
    pub fn check_resources(&self, k: usize, d: usize, g: usize) -> Result<ResourceEstimate> {
        let shape = ProblemShape::new(k, d, g, self.cfg.tile_points);
        let est = resource::estimate(&self.cfg.pipeline, &self.cfg.filter, &shape);
        est.check(&self.cfg.part)?;
        Ok(est)
    }

    /// Run a complete K-means fit on the simulated accelerator.
    ///
    /// `init` must come from `kmeans::init::initialize` with the same
    /// config for results to be comparable with the software algorithms.
    pub fn run_fit(
        &self,
        ds: &Dataset,
        cfg: &KMeansConfig,
        init: Matrix,
    ) -> Result<AccelRunResult> {
        cfg.validate(ds.n())?;
        let n = ds.n();
        let d = ds.d();
        let k = cfg.k;
        let n_groups = if self.cfg.enable_filters {
            cfg.effective_groups().clamp(1, k)
        } else {
            1
        };
        let resources = self.check_resources(k, d, n_groups)?;

        let mut centroids = init;
        let grouping = group_centroids(&centroids, n_groups, cfg.seed);
        let mut stats = RunStats::default();
        let mut iter_cycles: Vec<CycleBreakdown> = Vec::new();
        let mut converged = false;
        let mut iterations = 0usize;
        let mut dma_bytes_total = 0u64;

        // ---- Iteration 1: full scan (filters bypassed, bounds seeded) ----
        let (mut st, init_dists) = FilterState::init_full_scan(ds, &centroids, &grouping);
        let mut drifts;
        let mut group_drifts;
        {
            iterations += 1;
            let mut it = IterStats::default();
            it.dist_comps = init_dists;
            it.survivors = n as u64;
            it.reassigned = n as u64;
            let (cyc, bytes) = self.iteration_cycles_full_scan(n, d, k, n_groups);
            dma_bytes_total += bytes;
            let (new_c, _) = recompute_centroids(ds, &st.assignments, &centroids);
            let (dr, max_drift) = centroid_drifts(&centroids, &new_c);
            centroids = new_c;
            it.max_drift = max_drift;
            stats.push(it);
            iter_cycles.push(cyc);
            group_drifts = group_max_drifts(&dr, &grouping.group_of, grouping.n_groups());
            drifts = dr;
            if (max_drift as f64) <= cfg.tol {
                converged = true;
            } else if self.cfg.enable_filters {
                st.apply_drifts(&drifts, &group_drifts);
            }
        }

        // ---- Filtered iterations ----
        while !converged && iterations < cfg.max_iters {
            iterations += 1;
            let mut it = IterStats::default();
            let tile = self.cfg.tile_points;
            let mut tile_compute: Vec<(u64, u64)> = Vec::new(); // (filter, pipeline)

            let mut t_start = 0usize;
            while t_start < n {
                let t_end = (t_start + tile).min(n);
                let mut tile_dists = 0u64;
                let mut filter_cycles = 0u64;
                for i in t_start..t_end {
                    let row = ds.points.row(i);
                    if self.cfg.enable_filters {
                        let c = step_point(
                            row, &centroids, &grouping, &drifts, &group_drifts, i, &mut st,
                        );
                        it.dist_comps += c.dists as u64;
                        it.filtered_group += c.groups_skipped as u64;
                        it.filtered_point += c.points_skipped as u64;
                        if c.globally_filtered {
                            it.filtered_global += 1;
                        } else {
                            it.survivors += 1;
                        }
                        if c.reassigned {
                            it.reassigned += 1;
                        }
                        tile_dists += c.dists as u64;
                        // Filter stage II per point: its sub-units pipeline
                        // against each other, so a point costs the max.
                        filter_cycles += self
                            .cfg
                            .filter
                            .drift_update_cycles(n_groups)
                            .max(self.cfg.filter.global_test_cycles(n_groups))
                            .max(self.cfg.filter.writeback_cycles(n_groups));
                    } else {
                        let (arg, _, _) = scan_all(row, &centroids);
                        if st.assignments[i] != arg as u32 {
                            it.reassigned += 1;
                            st.assignments[i] = arg as u32;
                        }
                        it.dist_comps += k as u64;
                        it.survivors += 1;
                        tile_dists += k as u64;
                        filter_cycles += 1; // stream-through commit slot
                    }
                }
                let pipe_cycles = self.cfg.pipeline.cycles(tile_dists, d);
                tile_compute.push((filter_cycles, pipe_cycles));
                t_start = t_end;
            }

            let (cyc, bytes) =
                self.assemble_iteration(&tile_compute, n, d, k, n_groups, self.cfg.enable_filters);
            dma_bytes_total += bytes;

            let (new_c, _) = recompute_centroids(ds, &st.assignments, &centroids);
            let (dr, max_drift) = centroid_drifts(&centroids, &new_c);
            centroids = new_c;
            it.max_drift = max_drift;
            stats.push(it);
            iter_cycles.push(cyc);
            group_drifts = group_max_drifts(&dr, &grouping.group_of, grouping.n_groups());
            drifts = dr;

            if (max_drift as f64) <= cfg.tol {
                converged = true;
            } else if self.cfg.enable_filters {
                st.apply_drifts(&drifts, &group_drifts);
            }
        }

        let inertia = compute_inertia(ds, &centroids, &st.assignments);
        let total_cycles: u64 = iter_cycles.iter().map(|c| c.total).sum();
        let pipeline_busy: u64 = iter_cycles.iter().map(|c| c.pipeline).sum();
        let seconds = self.cfg.part.pl_seconds(total_cycles);
        Ok(AccelRunResult {
            fit: FitResult {
                centroids,
                assignments: st.assignments,
                inertia,
                iterations,
                converged,
                stats,
            },
            iters: iter_cycles,
            total_cycles,
            seconds,
            pipeline_utilization: if total_cycles > 0 {
                pipeline_busy as f64 / total_cycles as f64
            } else {
                0.0
            },
            dma_bytes: dma_bytes_total,
            resources,
        })
    }

    /// Tile DMA transfers for one filtered iteration: the point stream is
    /// split across two HP ports (the Zynq has four; KPynq dedicates two
    /// to the point slab), bounds + prior assignments ride a third, and
    /// results return on the fourth — all concurrent, sharing DDR.
    fn tile_transfers(&self, pts: usize, d: usize, g: usize, filters: bool) -> Vec<Transfer> {
        let p = pts as u64;
        let d = d as u64;
        let g = g as u64;
        let point_bytes = p * d * FEATURE_BYTES;
        let mut ts = vec![
            Transfer { bytes: point_bytes / 2, dir: Dir::ToPl },
            Transfer { bytes: point_bytes - point_bytes / 2, dir: Dir::ToPl },
        ];
        if filters {
            ts.push(Transfer { bytes: p * (1 + g) * BOUND_BYTES + p * 2, dir: Dir::ToPl });
            ts.push(Transfer { bytes: p * 2 + p * (1 + g) * BOUND_BYTES, dir: Dir::FromPl });
        } else {
            ts.push(Transfer { bytes: p * 2, dir: Dir::FromPl });
        }
        ts
    }

    /// Compose an iteration's makespan from per-tile compute costs with
    /// double-buffered DMA overlap, plus the PS update step.
    fn assemble_iteration(
        &self,
        tile_compute: &[(u64, u64)],
        n: usize,
        d: usize,
        k: usize,
        g: usize,
        filters: bool,
    ) -> (CycleBreakdown, u64) {
        let tile = self.cfg.tile_points;
        let mut cyc = CycleBreakdown::default();
        let mut bytes_total = 0u64;

        // Centroid broadcast at iteration start (both clock-domain copies).
        let centroid_bytes = (k * d) as u64 * FEATURE_BYTES;
        let centroid_dma = self
            .dma
            .transfer_cycles(Transfer { bytes: centroid_bytes, dir: Dir::ToPl });
        bytes_total += centroid_bytes;

        let mut pts_left = n;
        let mut makespan = centroid_dma;
        let mut prev_compute_end = makespan;
        for (idx, &(filt_c, pipe_c)) in tile_compute.iter().enumerate() {
            let pts = tile.min(pts_left);
            pts_left -= pts;
            let transfers = self.tile_transfers(pts, d, g, filters);
            bytes_total += transfers.iter().map(|t| t.bytes).sum::<u64>();
            let dma_in = self.dma.concurrent(&transfers);
            // The filter and pipeline stages of one tile are themselves
            // pipelined point-streams: tile compute ≈ max of the stages
            // plus one pipeline drain.
            let compute = filt_c.max(pipe_c) + self.cfg.pipeline.depth();
            // Double buffering: tile i's DMA overlaps tile i-1's compute.
            let dma_done = makespan + dma_in;
            let compute_start = dma_done.max(prev_compute_end);
            prev_compute_end = compute_start + compute;
            makespan = dma_done;
            cyc.dma_in += dma_in;
            cyc.filter += filt_c;
            cyc.pipeline += pipe_c;
            if idx + 1 == tile_compute.len() {
                makespan = prev_compute_end;
            }
        }
        // Final result drain already included per-tile via b_out overlap;
        // charge the residual out-transfer visibility as dma_out.
        cyc.dma_out = 0;

        // PS update: k·d divisions + drift norms (~6 ops each) at PS clock,
        // plus one DMA setup for the next centroid broadcast.
        let ps_ops = (k * d) as f64 * 6.0 + (k * d) as f64 * 2.0;
        let ps_seconds = ps_ops / self.cfg.part.ps_clock_hz + 1.0e-6;
        cyc.ps_update = self.cfg.part.pl_cycles(ps_seconds);

        cyc.total = makespan + cyc.ps_update;
        (cyc, bytes_total)
    }

    /// Iteration-1 (full scan) cycles: no bounds traffic, pipeline does
    /// n·k distances, the filter stage only streams commits.
    fn iteration_cycles_full_scan(
        &self,
        n: usize,
        d: usize,
        k: usize,
        g: usize,
    ) -> (CycleBreakdown, u64) {
        let tile = self.cfg.tile_points;
        let n_tiles = n.div_ceil(tile);
        let mut tile_compute = Vec::with_capacity(n_tiles);
        let mut pts_left = n;
        for _ in 0..n_tiles {
            let pts = tile.min(pts_left);
            pts_left -= pts;
            let dists = (pts * k) as u64;
            // Bound writeback happens even on iteration 1 (seeding).
            let filt = pts as u64 * self.cfg.filter.writeback_cycles(g);
            tile_compute.push((filt, self.cfg.pipeline.cycles(dists, d)));
        }
        self.assemble_iteration(&tile_compute, n, d, k, g, self.cfg.enable_filters)
    }

    /// Energy report against a CPU run time (see `energy::PowerModel`).
    pub fn energy(&self, run: &AccelRunResult, cpu_seconds: f64) -> super::energy::EnergyReport {
        self.cfg
            .power
            .compare(run.seconds, run.pipeline_utilization, cpu_seconds)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::kmeans::{self, init, Algorithm, InitMethod};

    fn kcfg(k: usize, groups: usize) -> KMeansConfig {
        KMeansConfig {
            k,
            groups,
            seed: 7,
            init: InitMethod::KMeansPlusPlus,
            ..Default::default()
        }
    }

    #[test]
    fn functional_output_matches_software_yinyang() {
        let ds = synth::blobs(1500, 16, 6, 3);
        let cfg = kcfg(6, 2);
        let c0 = init::initialize(&ds, &cfg).unwrap();
        let sw = kmeans::fit_from(Algorithm::Yinyang, &ds, &cfg, c0.clone()).unwrap();
        let acc = Accelerator::new(AccelConfig::default());
        let hw = acc.run_fit(&ds, &cfg, c0).unwrap();
        assert_eq!(sw.assignments, hw.fit.assignments);
        assert_eq!(sw.centroids, hw.fit.centroids);
        assert_eq!(sw.iterations, hw.fit.iterations);
        assert_eq!(sw.stats.total_dist_comps(), hw.fit.stats.total_dist_comps());
    }

    #[test]
    fn filters_disabled_matches_lloyd() {
        let ds = synth::blobs(800, 8, 4, 5);
        let cfg = kcfg(4, 0);
        let c0 = init::initialize(&ds, &cfg).unwrap();
        let sw = kmeans::fit_from(Algorithm::Lloyd, &ds, &cfg, c0.clone()).unwrap();
        let acc = Accelerator::new(AccelConfig { enable_filters: false, ..Default::default() });
        let hw = acc.run_fit(&ds, &cfg, c0).unwrap();
        assert_eq!(sw.assignments, hw.fit.assignments);
        assert_eq!(sw.centroids, hw.fit.centroids);
        assert_eq!(sw.iterations, hw.fit.iterations);
    }

    #[test]
    fn filters_reduce_cycles() {
        let ds = synth::blobs(4000, 32, 8, 9);
        let cfg = kcfg(16, 4);
        let c0 = init::initialize(&ds, &cfg).unwrap();
        let on = Accelerator::new(AccelConfig::default())
            .run_fit(&ds, &cfg, c0.clone())
            .unwrap();
        let off = Accelerator::new(AccelConfig { enable_filters: false, ..Default::default() })
            .run_fit(&ds, &cfg, c0)
            .unwrap();
        // Same clustering, fewer cycles with the multi-level filter on.
        assert_eq!(on.fit.assignments, off.fit.assignments);
        assert!(
            on.total_cycles < off.total_cycles,
            "filters on {} vs off {}",
            on.total_cycles,
            off.total_cycles
        );
    }

    #[test]
    fn breakdown_totals_are_consistent() {
        let ds = synth::blobs(1000, 16, 4, 11);
        let cfg = kcfg(8, 2);
        let c0 = init::initialize(&ds, &cfg).unwrap();
        let run = Accelerator::new(AccelConfig::default()).run_fit(&ds, &cfg, c0).unwrap();
        assert_eq!(run.iters.len(), run.fit.iterations);
        for it in &run.iters {
            assert!(it.total > 0);
            // Overlap can hide stage time but never create it: the makespan
            // is bounded by the serial sum.
            assert!(it.total <= it.serial_sum() + 1);
        }
        assert!(run.seconds > 0.0);
        assert!(run.pipeline_utilization > 0.0 && run.pipeline_utilization <= 1.0);
        assert!(run.dma_bytes > 0);
    }

    #[test]
    fn oversized_config_is_rejected() {
        let acc = Accelerator::new(AccelConfig {
            pipeline: PipelineConfig { lanes: 64, mac_width: 16 },
            ..Default::default()
        });
        let ds = synth::blobs(512, 16, 4, 13);
        let cfg = kcfg(8, 2);
        let c0 = init::initialize(&ds, &cfg).unwrap();
        assert!(acc.run_fit(&ds, &cfg, c0).is_err());
    }

    #[test]
    fn energy_report_is_positive_and_scaled() {
        let ds = synth::blobs(1000, 8, 4, 17);
        let cfg = kcfg(4, 1);
        let c0 = init::initialize(&ds, &cfg).unwrap();
        let acc = Accelerator::new(AccelConfig::default());
        let run = acc.run_fit(&ds, &cfg, c0).unwrap();
        let rep = acc.energy(&run, run.seconds * 3.0);
        assert!(rep.fpga_joules > 0.0);
        assert!(rep.efficiency_ratio > 3.0, "at 3x speedup the ratio must exceed 3");
    }
}
