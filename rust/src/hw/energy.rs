//! Power and energy model.
//!
//! The paper reports energy-efficiency ratios (up to 218×, 150.90× average)
//! alongside much smaller speedups (up to 4.2×, 2.95× average). The implied
//! power ratio is remarkably consistent: 218/4.2 ≈ 51.9 and 150.9/2.95 ≈
//! 51.2 — i.e. the CPU baseline burns ~51× the board power. That pins the
//! model: a ~95 W desktop-class CPU package against a ~1.85 W Pynq-Z1
//! (Zynq-7020 budgets: ~0.24 W static PL, ~0.6 W dynamic PL at full
//! datapath activity, ~1.4 W PS + DDR + board). The anchor is the
//! *operating point*: at the ~35% pipeline utilisation the simulated runs
//! report, board power ≈ 1.85 W and the ratio ≈ 51× — the paper's implied
//! value. Components stay explicit so the ablation benches can show how
//! energy scales with utilisation rather than hard-coding the ratio.

/// Power parameters (watts).
#[derive(Clone, Debug)]
pub struct PowerModel {
    /// PL static leakage.
    pub pl_static_w: f64,
    /// PL dynamic at 100% datapath activity (scaled by utilisation).
    pub pl_dynamic_w: f64,
    /// PS core + DDR + board overhead while the accelerator runs.
    pub board_base_w: f64,
    /// CPU baseline package power under K-means load.
    pub cpu_w: f64,
}

impl Default for PowerModel {
    fn default() -> Self {
        Self {
            pl_static_w: 0.24,
            pl_dynamic_w: 0.60,
            board_base_w: 1.40,
            cpu_w: 95.0,
        }
    }
}

/// Energy figures for one run.
#[derive(Clone, Copy, Debug)]
pub struct EnergyReport {
    pub fpga_joules: f64,
    pub cpu_joules: f64,
    /// cpu_joules / fpga_joules — the paper's "energy-efficiency" metric.
    pub efficiency_ratio: f64,
}

impl PowerModel {
    /// Board power while the accelerator runs at `utilization` ∈ [0, 1]
    /// (fraction of cycles the datapath is active, from the cycle model).
    pub fn board_power(&self, utilization: f64) -> f64 {
        self.board_base_w + self.pl_static_w + self.pl_dynamic_w * utilization.clamp(0.0, 1.0)
    }

    /// Energy comparison for an accelerator run of `fpga_seconds` at
    /// `utilization` against a CPU run of `cpu_seconds`.
    pub fn compare(&self, fpga_seconds: f64, utilization: f64, cpu_seconds: f64) -> EnergyReport {
        let fpga_joules = self.board_power(utilization) * fpga_seconds;
        let cpu_joules = self.cpu_w * cpu_seconds;
        EnergyReport {
            fpga_joules,
            cpu_joules,
            efficiency_ratio: cpu_joules / fpga_joules,
        }
    }

    /// The power ratio at the typical operating utilisation (~35% datapath
    /// activity in the simulated runs) — the factor linking speedup to
    /// energy-efficiency (≈ 51 with default parameters, matching the
    /// paper's implied 150.90/2.95 ≈ 218/4.2 ≈ 51).
    pub fn operating_power_ratio(&self) -> f64 {
        self.cpu_w / self.board_power(0.35)
    }

    /// The power ratio at full datapath activity (lower bound on the
    /// ratio; utilisation can only help the FPGA).
    pub fn full_power_ratio(&self) -> f64 {
        self.cpu_w / self.board_power(1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_ratio_matches_papers_implication() {
        let m = PowerModel::default();
        let r = m.operating_power_ratio();
        // 150.90 / 2.95 = 51.15 and 218 / 4.2 = 51.9 — the model must land
        // in that band at the operating utilisation.
        assert!((49.0..54.0).contains(&r), "power ratio {r}");
        assert!(m.full_power_ratio() < r, "full activity draws more");
    }

    #[test]
    fn energy_efficiency_is_speedup_times_power_ratio() {
        let m = PowerModel::default();
        let cpu_s = 10.0;
        let fpga_s = cpu_s / 2.95; // the paper's average speedup
        let rep = m.compare(fpga_s, 0.35, cpu_s);
        let expected = 2.95 * m.operating_power_ratio();
        assert!(
            (rep.efficiency_ratio - expected).abs() < 1e-9,
            "{} vs {}",
            rep.efficiency_ratio,
            expected
        );
        // And the band includes the paper's 150.90×.
        assert!((140.0..160.0).contains(&rep.efficiency_ratio));
    }

    #[test]
    fn idle_logic_draws_less() {
        let m = PowerModel::default();
        assert!(m.board_power(0.0) < m.board_power(1.0));
        let low = m.compare(1.0, 0.1, 1.0);
        let high = m.compare(1.0, 0.9, 1.0);
        assert!(low.fpga_joules < high.fpga_joules);
    }
}
