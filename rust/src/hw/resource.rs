//! Resource estimation: which accelerator configurations fit which part.
//!
//! The paper's §I claim — "much more scalable and highly configurable
//! equipped with a set of tunable parameters (e.g. degree of parallelism),
//! which help to handle various datasets" — is only meaningful if the
//! tunables are checked against the part's LUT/FF/DSP/BRAM budget. This
//! module prices a configuration for a given problem shape and reports
//! what binds first; `fig_parallelism_sweep` regenerates the resulting
//! lane-count frontier.

use super::bram::blocks_for;
use super::filter_unit::FilterUnitConfig;
use super::pipeline::PipelineConfig;
use super::zynq::ZynqPart;
use crate::error::{Error, Result};

/// Static problem geometry the bitstream is built for.
#[derive(Clone, Copy, Debug)]
pub struct ProblemShape {
    /// Max clusters supported by the centroid bank.
    pub k: usize,
    /// Max dimensionality.
    pub d: usize,
    /// Max filter groups.
    pub g: usize,
    /// Streaming tile size in points.
    pub tile_points: usize,
}

impl ProblemShape {
    pub fn new(k: usize, d: usize, g: usize, tile_points: usize) -> Self {
        Self { k, d, g, tile_points }
    }
}

/// Estimated resource usage of one configuration.
#[derive(Clone, Debug)]
pub struct ResourceEstimate {
    pub luts: u64,
    pub ffs: u64,
    pub dsp: u64,
    pub bram_18k: u64,
    /// Per-buffer BRAM breakdown: (name, blocks).
    pub bram_detail: Vec<(String, u64)>,
}

impl ResourceEstimate {
    /// Check against a part; the error names the binding resource.
    pub fn check(&self, part: &ZynqPart) -> Result<()> {
        let mut over = Vec::new();
        if self.luts > part.luts {
            over.push(format!("LUT {}/{}", self.luts, part.luts));
        }
        if self.ffs > part.ffs {
            over.push(format!("FF {}/{}", self.ffs, part.ffs));
        }
        if self.dsp > part.dsp {
            over.push(format!("DSP {}/{}", self.dsp, part.dsp));
        }
        if self.bram_18k > part.bram_18k {
            over.push(format!("BRAM_18K {}/{}", self.bram_18k, part.bram_18k));
        }
        if over.is_empty() {
            Ok(())
        } else {
            Err(Error::Resource { part: part.name.to_string(), detail: over.join(", ") })
        }
    }

    pub fn fits(&self, part: &ZynqPart) -> bool {
        self.check(part).is_ok()
    }

    /// Utilisation of the scarcest resource, in [0, ∞).
    pub fn max_utilization(&self, part: &ZynqPart) -> f64 {
        [
            self.luts as f64 / part.luts as f64,
            self.ffs as f64 / part.ffs as f64,
            self.dsp as f64 / part.dsp as f64,
            self.bram_18k as f64 / part.bram_18k as f64,
        ]
        .into_iter()
        .fold(0.0, f64::max)
    }
}

/// Bytes per stored feature: 16-bit fixed point (Q1.15) in the datapath.
pub const FEATURE_BYTES: u64 = 2;
/// Bytes per bound value (ub or group lb): 16-bit fixed point.
pub const BOUND_BYTES: u64 = 2;
/// Bytes per accumulator word (cluster sums): 32-bit.
pub const ACC_BYTES: u64 = 4;

/// Price a configuration.
pub fn estimate(
    pipe: &PipelineConfig,
    filt: &FilterUnitConfig,
    shape: &ProblemShape,
) -> ResourceEstimate {
    let lanes = pipe.lanes;
    let w = pipe.mac_width;
    let (k, d, g, tile) = (
        shape.k as u64,
        shape.d as u64,
        shape.g as u64,
        shape.tile_points as u64,
    );

    let mut bram_detail = Vec::new();
    let mut bram = 0u64;
    let add = |name: &str, bytes: u64, banks: u64, detail: &mut Vec<(String, u64)>| {
        let blocks = blocks_for(bytes, banks);
        detail.push((name.to_string(), blocks));
        blocks
    };

    // Point tile: block-partitioned over lanes (each lane owns tile/lanes
    // points) and cyclically over mac_width in the dim axis, double
    // buffered against the DMA stream.
    bram += add(
        "points (x2 dbl-buf)",
        2 * tile * d * FEATURE_BYTES,
        lanes * w,
        &mut bram_detail,
    );
    // Centroid bank: every lane reads a (different) centroid row each
    // slot; cyclic over mac_width, replicated per-lane read port via
    // double-pumping two lanes per bank → lanes/2 × w banks; double
    // buffered for the PS's next-iteration write.
    bram += add(
        "centroids (x2 dbl-buf)",
        2 * k * d * FEATURE_BYTES,
        (lanes.div_ceil(2)).max(1) * w,
        &mut bram_detail,
    );
    // Bound tile: ub + g lower bounds per point, streamed like the points.
    bram += add(
        "bounds (x2 dbl-buf)",
        2 * tile * (1 + g) * BOUND_BYTES,
        4,
        &mut bram_detail,
    );
    // Assignment tile (in + out).
    bram += add("assignments", 2 * tile * 2, 2, &mut bram_detail);
    // Cluster-sum accumulators + counts (one copy, wide words).
    bram += add("accumulators", k * d * ACC_BYTES + k * 4, w, &mut bram_detail);

    // DSPs: the MAC tree plus 2 for the fixed-point drift/bound arithmetic.
    let dsp = pipe.dsp_used() + 2;

    // LUTs: control/FSM base, per-lane steering + accumulate, filter
    // comparators, DMA/AXIS glue, PS mailbox.
    let luts = 3_000 + 450 * lanes + 40 * lanes * w + filt.luts() + 1_800;
    // FFs: pipeline registers dominate — depth × lanes × datapath width.
    let ffs = 4_000 + pipe.depth() * lanes * 48 + 600;

    ResourceEstimate { luts, ffs, dsp, bram_18k: bram, bram_detail }
}

/// Largest lane count that fits `part` for the shape (mac_width fixed).
pub fn max_lanes(
    part: &ZynqPart,
    filt: &FilterUnitConfig,
    shape: &ProblemShape,
    mac_width: u64,
) -> u64 {
    let mut best = 0;
    for lanes in 1..=64 {
        let pipe = PipelineConfig { lanes, mac_width };
        if estimate(&pipe, filt, shape).fits(part) {
            best = lanes;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shape() -> ProblemShape {
        ProblemShape::new(16, 64, 8, 256)
    }

    #[test]
    fn default_config_fits_7020() {
        let part = ZynqPart::xc7z020();
        let pipe = PipelineConfig { lanes: 8, mac_width: 8 };
        let est = estimate(&pipe, &FilterUnitConfig::default(), &shape());
        est.check(&part).unwrap();
        assert!(est.max_utilization(&part) < 1.0);
    }

    #[test]
    fn estimates_are_monotone_in_lanes() {
        // DSP and LUT grow strictly with lanes; BRAM is bank-granular (it
        // can locally dip as per-bank rounding repacks) but must always
        // cover at least one block per bank of the widest buffer.
        let filt = FilterUnitConfig::default();
        let mut last = estimate(&PipelineConfig { lanes: 1, mac_width: 4 }, &filt, &shape());
        for lanes in 2..=32 {
            let est = estimate(&PipelineConfig { lanes, mac_width: 4 }, &filt, &shape());
            assert!(est.dsp > last.dsp);
            assert!(est.luts > last.luts);
            assert!(est.bram_18k >= lanes * 4, "points buffer has lanes*w banks");
            last = est;
        }
    }

    #[test]
    fn something_binds_eventually_on_7020() {
        let part = ZynqPart::xc7z020();
        let m = max_lanes(&part, &FilterUnitConfig::default(), &shape(), 8);
        assert!(m >= 4, "at least a few lanes must fit, got {m}");
        assert!(m < 64, "the 7020 cannot be unbounded, got {m}");
        let too_big = PipelineConfig { lanes: m + 1, mac_width: 8 };
        assert!(!estimate(&too_big, &FilterUnitConfig::default(), &shape()).fits(&part));
    }

    #[test]
    fn bigger_part_fits_more_lanes() {
        let filt = FilterUnitConfig::default();
        let small = max_lanes(&ZynqPart::xc7z020(), &filt, &shape(), 8);
        let big = max_lanes(&ZynqPart::zu7ev(), &filt, &shape(), 8);
        assert!(big > small, "{big} vs {small}");
    }

    #[test]
    fn high_dimension_costs_more_bram() {
        let filt = FilterUnitConfig::default();
        let pipe = PipelineConfig { lanes: 8, mac_width: 8 };
        // Bank granularity absorbs small d changes (16 → 128 both fit one
        // block per bank); a big jump must show up.
        let lo = estimate(&pipe, &filt, &ProblemShape::new(16, 16, 8, 256));
        let hi = estimate(&pipe, &filt, &ProblemShape::new(16, 512, 8, 256));
        assert!(hi.bram_18k > lo.bram_18k);
    }

    #[test]
    fn bram_detail_sums_to_total() {
        let pipe = PipelineConfig { lanes: 4, mac_width: 4 };
        let est = estimate(&pipe, &FilterUnitConfig::default(), &shape());
        let sum: u64 = est.bram_detail.iter().map(|(_, b)| b).sum();
        assert_eq!(sum, est.bram_18k);
        // Sanity: detail covers the five architectural buffers.
        assert_eq!(est.bram_detail.len(), 5);
    }
}
