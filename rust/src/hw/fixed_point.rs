//! Q-format fixed-point analysis for the PL datapath.
//!
//! The Artix-7 DSP48E1 is a 25×18-bit multiplier; KPynq-class designs run
//! the distance datapath in 16-bit fixed point on min-max-normalised data.
//! The *functional* simulation uses f32 (so the exactness property against
//! Lloyd holds bit-for-bit); this module quantifies what the silicon would
//! lose: quantisation of inputs, products and the accumulator. The
//! `fixed_point_fidelity` integration test uses it to show that on
//! normalised data, Q1.15 inputs with a Q12.20 accumulator reproduce f32
//! assignments for >99.9% of points — the justification for modelling the
//! datapath functionally in f32 (DESIGN.md §1).

/// A signed fixed-point format with `frac` fractional bits in `bits` total.
#[derive(Clone, Copy, Debug)]
pub struct QFormat {
    pub bits: u32,
    pub frac: u32,
}

impl QFormat {
    /// Q1.15: the 16-bit input format for normalised features.
    pub const Q1_15: QFormat = QFormat { bits: 16, frac: 15 };
    /// Q12.20: 32-bit accumulator with headroom for d ≤ 2048 sums of
    /// unit-range squared terms.
    pub const Q12_20: QFormat = QFormat { bits: 32, frac: 20 };

    pub fn step(&self) -> f64 {
        2.0f64.powi(-(self.frac as i32))
    }

    pub fn max_value(&self) -> f64 {
        2.0f64.powi(self.bits as i32 - 1 - self.frac as i32) - self.step()
    }

    pub fn min_value(&self) -> f64 {
        -2.0f64.powi(self.bits as i32 - 1 - self.frac as i32)
    }

    /// Quantise (round-to-nearest, saturating).
    pub fn quantize(&self, x: f64) -> f64 {
        let clamped = x.clamp(self.min_value(), self.max_value());
        (clamped / self.step()).round() * self.step()
    }

    /// Quantise an f32 slice into a new Vec (for fidelity experiments).
    pub fn quantize_slice(&self, xs: &[f32]) -> Vec<f32> {
        xs.iter().map(|&x| self.quantize(x as f64) as f32).collect()
    }

    /// Worst-case absolute error of a d-dim squared distance computed with
    /// inputs in this format (each coordinate error ≤ step/2, differences
    /// double it; first-order bound for |x|,|c| ≤ 1).
    pub fn sq_dist_error_bound(&self, d: usize) -> f64 {
        // |(x+e1 - c-e2)^2 - (x-c)^2| ≤ 2|x-c||e1-e2| + (e1-e2)^2,
        // with |x-c| ≤ 1 and |e1-e2| ≤ step: per-dim ≈ 2·step.
        2.0 * self.step() * d as f64 + self.step() * self.step() * d as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn q115_range_and_step() {
        let q = QFormat::Q1_15;
        assert!((q.step() - 3.0517578125e-5).abs() < 1e-15);
        assert!((q.max_value() - (1.0 - q.step())).abs() < 1e-12);
        assert_eq!(q.min_value(), -1.0);
    }

    #[test]
    fn quantize_rounds_and_saturates() {
        let q = QFormat::Q1_15;
        assert_eq!(q.quantize(0.5), 0.5); // exactly representable
        assert_eq!(q.quantize(10.0), q.max_value());
        assert_eq!(q.quantize(-10.0), -1.0);
        let x = 0.123456789;
        assert!((q.quantize(x) - x).abs() <= q.step() / 2.0 + 1e-15);
    }

    #[test]
    fn error_bound_is_small_for_normalized_data() {
        // d=128 normalised features: error bound ≪ typical inter-centroid
        // squared distances (~1e-2 after min-max scaling).
        let b = QFormat::Q1_15.sq_dist_error_bound(128);
        assert!(b < 1e-2, "bound {b}");
    }

    #[test]
    fn accumulator_holds_worst_case_sum() {
        // Worst-case squared distance on [0,1]^1024 data is 1024 ≤ Q12.20 max.
        assert!(QFormat::Q12_20.max_value() >= 1024.0);
    }
}
