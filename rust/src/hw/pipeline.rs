//! The Distance Calculator: a lane-parallel, fully pipelined MAC datapath.
//!
//! KPynq's compute stage: `lanes` independent distance units, each built
//! from `mac_width` DSP48 multiply-accumulators feeding a balanced adder
//! tree, initiation interval 1. One (point, centroid) distance of
//! dimensionality `d` occupies a lane for `ceil(d / mac_width)` issue
//! slots; the pipeline's depth (multiplier stages + adder tree +
//! accumulate + sqrt approx) is paid once per drain.
//!
//! The model is deliberately *work-driven*: the accelerator hands it the
//! exact number of distances the filter let through (from
//! `yinyang::StepCounts`), and it converts work → cycles. That keeps the
//! timing faithful to the paper's architecture (compute scales with
//! surviving work, not with n·k) without simulating every register.

/// Configuration of the distance pipeline.
#[derive(Clone, Copy, Debug)]
pub struct PipelineConfig {
    /// Parallel distance lanes (the paper's "degree of parallelism").
    pub lanes: u64,
    /// MACs per lane per cycle (DSP48s in the dot-product tree).
    pub mac_width: u64,
}

impl PipelineConfig {
    /// DSPs consumed: one DSP48E1 per fixed-point MAC, plus one per lane
    /// for the subtract-square pre-stage sharing.
    pub fn dsp_used(&self) -> u64 {
        self.lanes * (self.mac_width + 1)
    }

    /// Pipeline depth in cycles: subtract (1) + multiply (3) + adder tree
    /// (log2 width) + accumulate (1) + compare/commit (1).
    pub fn depth(&self) -> u64 {
        let tree = 64 - (self.mac_width.max(1) - 1).leading_zeros() as u64;
        6 + tree
    }

    /// Issue slots one distance of dimension `d` occupies on a lane.
    pub fn slots_per_distance(&self, d: usize) -> u64 {
        (d as u64).div_ceil(self.mac_width)
    }

    /// Cycles to compute `n_distances` distances of dimension `d`, spread
    /// over the lanes, including one drain.
    pub fn cycles(&self, n_distances: u64, d: usize) -> u64 {
        if n_distances == 0 {
            return 0;
        }
        let slots = n_distances * self.slots_per_distance(d);
        slots.div_ceil(self.lanes) + self.depth()
    }

    /// Peak MACs per second at the given clock.
    pub fn peak_macs_per_sec(&self, clock_hz: f64) -> f64 {
        (self.lanes * self.mac_width) as f64 * clock_hz
    }

    /// Fraction of peak MAC throughput achieved for a workload that needed
    /// `n_distances` distances of dimension `d` in `total_cycles`.
    pub fn utilization(&self, n_distances: u64, d: usize, total_cycles: u64) -> f64 {
        if total_cycles == 0 {
            return 0.0;
        }
        let useful_macs = n_distances * d as u64;
        let peak = total_cycles * self.lanes * self.mac_width;
        useful_macs as f64 / peak as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dsp_accounting() {
        let p = PipelineConfig { lanes: 16, mac_width: 8 };
        assert_eq!(p.dsp_used(), 16 * 9);
    }

    #[test]
    fn slots_round_up() {
        let p = PipelineConfig { lanes: 4, mac_width: 8 };
        assert_eq!(p.slots_per_distance(8), 1);
        assert_eq!(p.slots_per_distance(9), 2);
        assert_eq!(p.slots_per_distance(1), 1);
        assert_eq!(p.slots_per_distance(64), 8);
    }

    #[test]
    fn cycles_scale_linearly_with_work() {
        let p = PipelineConfig { lanes: 8, mac_width: 4 };
        let base = p.cycles(1_000, 16) - p.depth();
        let double = p.cycles(2_000, 16) - p.depth();
        assert_eq!(double, base * 2);
        assert_eq!(p.cycles(0, 16), 0);
    }

    #[test]
    fn more_lanes_never_slower() {
        for lanes in [1u64, 2, 4, 8, 16] {
            let a = PipelineConfig { lanes, mac_width: 4 }.cycles(10_000, 32);
            let b = PipelineConfig { lanes: lanes * 2, mac_width: 4 }.cycles(10_000, 32);
            assert!(b <= a, "lanes {lanes}: {b} > {a}");
        }
    }

    #[test]
    fn utilization_bounded_and_high_when_saturated() {
        let p = PipelineConfig { lanes: 8, mac_width: 8 };
        let n = 100_000u64;
        let d = 64usize;
        let cyc = p.cycles(n, d);
        let u = p.utilization(n, d, cyc);
        assert!(u <= 1.0);
        // d=64 is a multiple of mac_width → utilization near 1 at scale.
        assert!(u > 0.95, "u = {u}");
    }

    #[test]
    fn padding_loss_shows_in_utilization() {
        // d=9 on width 8 wastes 7/16 of slots.
        let p = PipelineConfig { lanes: 4, mac_width: 8 };
        let n = 50_000u64;
        let cyc = p.cycles(n, 9);
        let u = p.utilization(n, 9, cyc);
        assert!(u < 0.6, "u = {u}");
    }
}
