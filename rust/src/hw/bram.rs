//! BRAM banking and capacity accounting.
//!
//! The PL accelerator keeps four things on-chip: the centroid bank (double
//! buffered so the PS can write iteration t+1's centroids while t runs),
//! the streaming point tile (double buffered against DMA), the bound tile
//! and the per-cluster accumulators. Each allocation is carved from the
//! part's BRAM_18K blocks; an allocation partitioned across `banks` banks
//! for parallel access must round *each bank* up to whole 18 Kb blocks —
//! the granularity loss is real on the 7020 and is what ultimately caps the
//! lane count (see `resource::estimate` and the parallelism-sweep bench).

use crate::error::{Error, Result};

/// Bytes of data payload in one BRAM_18K block (2.25 KB: 18 Kb including
/// parity bits, matching the 280 × 18 Kb = 630 KB figure in the paper).
pub const BRAM_18K_BYTES: u64 = 2304;

/// One named allocation.
#[derive(Clone, Debug)]
pub struct Allocation {
    pub name: String,
    pub bytes: u64,
    /// Parallel banks the buffer is partitioned into (cyclic partition).
    pub banks: u64,
    /// BRAM_18K blocks consumed (≥ banks, each bank whole blocks).
    pub blocks: u64,
}

/// Blocks needed for `bytes` split evenly over `banks` banks.
pub fn blocks_for(bytes: u64, banks: u64) -> u64 {
    assert!(banks > 0, "banks must be >= 1");
    let per_bank = bytes.div_ceil(banks);
    let blocks_per_bank = per_bank.div_ceil(BRAM_18K_BYTES).max(1);
    blocks_per_bank * banks
}

/// A budget of BRAM_18K blocks with named allocations.
#[derive(Clone, Debug)]
pub struct BramBudget {
    capacity_blocks: u64,
    allocations: Vec<Allocation>,
}

impl BramBudget {
    pub fn new(capacity_blocks: u64) -> Self {
        Self { capacity_blocks, allocations: Vec::new() }
    }

    /// Allocate `bytes` partitioned over `banks`; errors on overflow.
    pub fn alloc(&mut self, name: &str, bytes: u64, banks: u64) -> Result<&Allocation> {
        let blocks = blocks_for(bytes, banks);
        if self.used_blocks() + blocks > self.capacity_blocks {
            return Err(Error::Resource {
                part: format!("BRAM ({} blocks)", self.capacity_blocks),
                detail: format!(
                    "allocation '{name}' needs {blocks} BRAM_18K, only {} free \
                     (used {} of {})",
                    self.capacity_blocks - self.used_blocks(),
                    self.used_blocks(),
                    self.capacity_blocks
                ),
            });
        }
        self.allocations.push(Allocation {
            name: name.to_string(),
            bytes,
            banks,
            blocks,
        });
        Ok(self.allocations.last().unwrap())
    }

    pub fn used_blocks(&self) -> u64 {
        self.allocations.iter().map(|a| a.blocks).sum()
    }

    pub fn free_blocks(&self) -> u64 {
        self.capacity_blocks - self.used_blocks()
    }

    pub fn allocations(&self) -> &[Allocation] {
        &self.allocations
    }

    /// Utilisation in [0, 1].
    pub fn utilization(&self) -> f64 {
        self.used_blocks() as f64 / self.capacity_blocks as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn blocks_round_up_per_bank() {
        // 1 byte still costs a whole block.
        assert_eq!(blocks_for(1, 1), 1);
        // Exactly one block.
        assert_eq!(blocks_for(BRAM_18K_BYTES, 1), 1);
        // One byte over → two blocks.
        assert_eq!(blocks_for(BRAM_18K_BYTES + 1, 1), 2);
        // Partitioned: 4 banks of 1 byte each = 4 blocks, not 1.
        assert_eq!(blocks_for(4, 4), 4);
        // 9 KB over 2 banks: 4.5 KB/bank → 2 blocks/bank → 4 total.
        assert_eq!(blocks_for(9 * 1024, 2), 4);
    }

    #[test]
    fn budget_tracks_and_overflows() {
        let mut b = BramBudget::new(10);
        b.alloc("centroids", 4 * BRAM_18K_BYTES, 1).unwrap();
        assert_eq!(b.used_blocks(), 4);
        assert_eq!(b.free_blocks(), 6);
        b.alloc("points", 2 * BRAM_18K_BYTES, 2).unwrap();
        assert_eq!(b.used_blocks(), 6);
        let err = b.alloc("too-big", 100 * BRAM_18K_BYTES, 1);
        assert!(matches!(err, Err(Error::Resource { .. })));
        // Failed allocation must not change state.
        assert_eq!(b.used_blocks(), 6);
        assert!((b.utilization() - 0.6).abs() < 1e-12);
    }

    #[test]
    fn partitioning_invariants() {
        // Block count is NOT monotone in banks (per-bank rounding can pack
        // better), but two invariants always hold: at least one block per
        // bank, and at least the raw capacity.
        let bytes = 10_000;
        for banks in 1..=16 {
            let blocks = blocks_for(bytes, banks);
            assert!(blocks >= banks, "banks={banks}");
            assert!(blocks * BRAM_18K_BYTES >= bytes, "banks={banks}");
        }
        // And heavy partitioning of a small buffer is pure waste.
        assert_eq!(blocks_for(64, 16), 16);
    }
}
