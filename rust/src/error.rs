//! Crate-wide error type.
//!
//! One flat enum rather than per-module errors: the coordinator surfaces
//! every failure to the CLI/examples anyway, and the variants carry enough
//! context (`String` payloads built at the failure site) to act on.

use thiserror::Error;

/// All errors the KPynq library can produce.
#[derive(Debug, Error)]
pub enum Error {
    /// Configuration rejected before any work started.
    #[error("invalid config: {0}")]
    Config(String),

    /// Dataset loading / generation / validation failure.
    #[error("dataset error: {0}")]
    Data(String),

    /// An accelerator configuration that does not fit the selected part.
    #[error("resource overflow on {part}: {detail}")]
    Resource { part: String, detail: String },

    /// The AOT artifact directory is missing or inconsistent.
    #[error("artifact error: {0}")]
    Artifact(String),

    /// PJRT/XLA runtime failure (compile or execute).
    #[error("xla runtime error: {0}")]
    Xla(String),

    /// JSON/TOML parse errors from the in-crate readers.
    #[error("parse error: {0}")]
    Parse(String),

    /// I/O wrapper.
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;
