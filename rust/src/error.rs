//! Crate-wide error type.
//!
//! One flat enum rather than per-module errors: the coordinator surfaces
//! every failure to the CLI/examples anyway, and the variants carry enough
//! context (`String` payloads built at the failure site) to act on.
//!
//! `Display` and `std::error::Error` are implemented by hand — the offline
//! crate universe has no `thiserror`, and a 40-line match is not worth a
//! proc-macro dependency on the build path.

use std::fmt;

/// All errors the KPynq library can produce.
#[derive(Debug)]
pub enum Error {
    /// Configuration rejected before any work started.
    Config(String),

    /// Dataset loading / generation / validation failure.
    Data(String),

    /// An accelerator configuration that does not fit the selected part.
    Resource { part: String, detail: String },

    /// The AOT artifact directory is missing or inconsistent.
    Artifact(String),

    /// PJRT/XLA runtime failure (compile or execute), or the `xla` feature
    /// being unavailable in this build.
    Xla(String),

    /// JSON/TOML parse errors from the in-crate readers.
    Parse(String),

    /// I/O wrapper.
    Io(std::io::Error),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Config(msg) => write!(f, "invalid config: {msg}"),
            Error::Data(msg) => write!(f, "dataset error: {msg}"),
            Error::Resource { part, detail } => {
                write!(f, "resource overflow on {part}: {detail}")
            }
            Error::Artifact(msg) => write!(f, "artifact error: {msg}"),
            Error::Xla(msg) => write!(f, "xla runtime error: {msg}"),
            Error::Parse(msg) => write!(f, "parse error: {msg}"),
            Error::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

#[cfg(feature = "xla")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_carries_context() {
        assert_eq!(
            Error::Config("k must be >= 1".into()).to_string(),
            "invalid config: k must be >= 1"
        );
        let r = Error::Resource { part: "XC7Z020".into(), detail: "DSP 300/220".into() };
        assert_eq!(r.to_string(), "resource overflow on XC7Z020: DSP 300/220");
    }

    #[test]
    fn io_errors_convert_and_chain() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: Error = io.into();
        assert!(e.to_string().contains("gone"));
        assert!(std::error::Error::source(&e).is_some());
    }
}
