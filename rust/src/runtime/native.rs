//! The native (pure-Rust) tile engine.
//!
//! Shares the tiled distance micro-kernel with the software algorithms
//! (`kmeans::kernel`, DESIGN.md §5), so a coordinator run through the
//! native engine is numerically identical to a direct `kmeans::fit` — the
//! anchor for all cross-engine parity tests.

use crate::error::Result;
use crate::kmeans::kernel;
use crate::util::matrix::Matrix;

use super::{AssignOut, Engine};

/// Zero-configuration native engine.
#[derive(Clone, Debug, Default)]
pub struct NativeEngine;

impl Engine for NativeEngine {
    fn name(&self) -> &'static str {
        "native"
    }

    fn assign_tile(&mut self, points: &Matrix, centroids: &Matrix) -> Result<AssignOut> {
        let scan = kernel::nearest_full_scan(points, centroids);
        Ok(AssignOut { idx: scan.idx, best: scan.best, second: scan.second })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    #[test]
    fn matches_scan_all_semantics() {
        let ds = synth::blobs(100, 6, 3, 1);
        let cents = ds.points.gather_rows(&[0, 10, 20]);
        let out = NativeEngine.assign_tile(&ds.points, &cents).unwrap();
        assert_eq!(out.idx.len(), 100);
        // Points 0/10/20 sit exactly on centroids.
        assert_eq!(out.idx[0], 0);
        assert_eq!(out.idx[10], 1);
        assert_eq!(out.idx[20], 2);
        assert!(out.best[0] <= 1e-12);
        // best <= second everywhere.
        for i in 0..100 {
            assert!(out.best[i] <= out.second[i]);
        }
    }

    #[test]
    fn k1_second_is_infinite() {
        let ds = synth::blobs(10, 3, 1, 2);
        let cents = ds.points.gather_rows(&[0]);
        let out = NativeEngine.assign_tile(&ds.points, &cents).unwrap();
        assert!(out.second.iter().all(|s| s.is_infinite()));
        assert!(out.idx.iter().all(|&i| i == 0));
    }
}
