//! The AOT artifact manifest (`artifacts/manifest.json`).
//!
//! Written by `python/compile/aot.py` at build time; read here at run time.
//! Each record describes one HLO-text module: its entry kind, the static
//! tile geometry it was traced for, and its I/O signature. The
//! [`Manifest::pick_assign`] selector implements the padding policy: a tile
//! of geometry (d, k) runs on the smallest exported variant that dominates
//! it, with the coordinator padding inputs and slicing outputs.

use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::util::json::Json;

/// Tensor signature (shape + dtype string, e.g. "f32"/"s32").
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSig {
    pub shape: Vec<usize>,
    pub dtype: String,
}

/// One exported module.
#[derive(Clone, Debug)]
pub struct ArtifactRecord {
    pub name: String,
    pub file: PathBuf,
    pub entry: String,
    pub tile_n: usize,
    pub d: usize,
    pub k: usize,
    pub g: usize,
    pub inputs: Vec<TensorSig>,
    pub outputs: Vec<TensorSig>,
    pub sha256: String,
}

/// The parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub tile_n: usize,
    pub artifacts: Vec<ArtifactRecord>,
    /// Directory the manifest was loaded from (files are relative to it).
    pub dir: PathBuf,
}

fn sigs(j: &Json) -> Result<Vec<TensorSig>> {
    j.as_arr()?
        .iter()
        .map(|t| {
            let shape = t
                .get("shape")?
                .as_arr()?
                .iter()
                .map(|v| v.as_usize())
                .collect::<Result<Vec<_>>>()?;
            Ok(TensorSig { shape, dtype: t.get("dtype")?.as_str()?.to_string() })
        })
        .collect()
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::Artifact(format!(
                "cannot read {} — run `make artifacts` first ({e})",
                path.display()
            ))
        })?;
        let j = Json::parse(&text)?;
        let version = j.get("version")?.as_usize()?;
        if version != 1 {
            return Err(Error::Artifact(format!("unsupported manifest version {version}")));
        }
        let tile_n = j.get("tile_n")?.as_usize()?;
        let mut artifacts = Vec::new();
        for rec in j.get("artifacts")?.as_arr()? {
            let file = dir.join(rec.get("file")?.as_str()?);
            if !file.exists() {
                return Err(Error::Artifact(format!(
                    "manifest names missing file {}",
                    file.display()
                )));
            }
            artifacts.push(ArtifactRecord {
                name: rec.get("name")?.as_str()?.to_string(),
                file,
                entry: rec.get("entry")?.as_str()?.to_string(),
                tile_n: rec.get("tile_n")?.as_usize()?,
                d: rec.get("d")?.as_usize()?,
                k: rec.get("k")?.as_usize()?,
                g: rec.get("g")?.as_usize()?,
                inputs: sigs(rec.get("inputs")?)?,
                outputs: sigs(rec.get("outputs")?)?,
                sha256: rec.get("sha256")?.as_str()?.to_string(),
            });
        }
        if artifacts.is_empty() {
            return Err(Error::Artifact("manifest has no artifacts".into()));
        }
        Ok(Manifest { tile_n, artifacts, dir: dir.to_path_buf() })
    }

    /// Smallest `assign` variant that dominates (d, k), by padded waste.
    pub fn pick_assign(&self, d: usize, k: usize) -> Result<&ArtifactRecord> {
        self.artifacts
            .iter()
            .filter(|a| a.entry == "assign" && a.d >= d && a.k >= k)
            .min_by_key(|a| a.d * a.k)
            .ok_or_else(|| {
                Error::Artifact(format!(
                    "no assign variant dominates d={d}, k={k} \
                     (exported: {:?})",
                    self.artifacts
                        .iter()
                        .filter(|a| a.entry == "assign")
                        .map(|a| (a.d, a.k))
                        .collect::<Vec<_>>()
                ))
            })
    }

    /// All records of one entry kind.
    pub fn by_entry(&self, entry: &str) -> Vec<&ArtifactRecord> {
        self.artifacts.iter().filter(|a| a.entry == entry).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_fake_manifest(dir: &Path, artifacts_json: &str) -> Result<Manifest> {
        std::fs::create_dir_all(dir).unwrap();
        let text = format!(
            r#"{{"version": 1, "tile_n": 256, "artifacts": [{artifacts_json}]}}"#
        );
        std::fs::write(dir.join("manifest.json"), text).unwrap();
        Manifest::load(dir)
    }

    fn record(name: &str, entry: &str, d: usize, k: usize) -> String {
        format!(
            r#"{{"name": "{name}", "file": "{name}.hlo.txt", "entry": "{entry}",
                "tile_n": 256, "d": {d}, "k": {k}, "g": 8,
                "inputs": [{{"shape": [256, {d}], "dtype": "f32"}}],
                "outputs": [{{"shape": [256], "dtype": "s32"}}],
                "sha256": "x"}}"#
        )
    }

    fn tmp(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("kpynq-manifest-{tag}-{}", std::process::id()))
    }

    #[test]
    fn loads_and_selects() {
        let dir = tmp("sel");
        std::fs::create_dir_all(&dir).unwrap();
        for n in ["a4", "a64", "a128"] {
            std::fs::write(dir.join(format!("{n}.hlo.txt")), "HloModule x").unwrap();
        }
        let arts = [
            record("a4", "assign", 4, 16),
            record("a64", "assign", 64, 16),
            record("a128", "assign", 128, 16),
        ]
        .join(",");
        let m = write_fake_manifest(&dir, &arts).unwrap();
        assert_eq!(m.tile_n, 256);
        assert_eq!(m.pick_assign(3, 8).unwrap().name, "a4");
        assert_eq!(m.pick_assign(5, 16).unwrap().name, "a64");
        assert_eq!(m.pick_assign(128, 16).unwrap().name, "a128");
        assert!(m.pick_assign(200, 16).is_err());
        assert!(m.pick_assign(4, 17).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_file_is_an_error() {
        let dir = tmp("missing");
        let err = write_fake_manifest(&dir, &record("ghost", "assign", 4, 16));
        assert!(matches!(err, Err(Error::Artifact(_))));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_manifest_mentions_make_artifacts() {
        let dir = tmp("none");
        std::fs::create_dir_all(&dir).unwrap();
        let err = Manifest::load(&dir).unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
        std::fs::remove_dir_all(&dir).ok();
    }
}
