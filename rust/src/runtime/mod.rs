//! Execution engines for the distance/assign hot tile.
//!
//! The coordinator dispatches dense survivor tiles to an [`Engine`]:
//!
//! * [`native::NativeEngine`] — the in-process Rust implementation (also
//!   the functional core of the hardware simulator).
//! * [`xla::XlaEngine`] — the AOT path: loads the HLO text modules that
//!   `python/compile/aot.py` lowered from the Layer-1 Pallas kernels,
//!   compiles them once on the PJRT CPU client, and executes them from the
//!   Rust request path. Python is never involved at run time. Requires the
//!   `xla` cargo feature; the default offline build ships a stub whose
//!   constructor reports the feature as unavailable.
//!
//! Both engines return *squared* distances with ties broken to the lowest
//! centroid index, so they are interchangeable; `engine_parity` integration
//! tests assert the XLA engine matches the native one on random tiles.

pub mod manifest;
pub mod native;
pub mod xla;

use crate::error::Result;
use crate::util::matrix::Matrix;

/// Output of an assign-tile dispatch.
#[derive(Clone, Debug, PartialEq)]
pub struct AssignOut {
    /// Nearest-centroid index per point.
    pub idx: Vec<u32>,
    /// Squared distance to the winner.
    pub best: Vec<f32>,
    /// Squared distance to the runner-up (`inf` when k == 1).
    pub second: Vec<f32>,
}

/// A tile executor.
pub trait Engine {
    fn name(&self) -> &'static str;

    /// Assign every row of `points` to its nearest row of `centroids`.
    fn assign_tile(&mut self, points: &Matrix, centroids: &Matrix) -> Result<AssignOut>;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn assign_out_equality_semantics() {
        let a = AssignOut { idx: vec![0], best: vec![1.0], second: vec![2.0] };
        let b = a.clone();
        assert_eq!(a, b);
    }
}
