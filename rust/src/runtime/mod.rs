//! Execution engines for the distance/assign hot tile.
//!
//! The coordinator dispatches dense survivor tiles to an [`Engine`]:
//!
//! * [`native::NativeEngine`] — the in-process Rust implementation (also
//!   the functional core of the hardware simulator).
//! * [`xla::XlaEngine`] — the AOT path: loads the HLO text modules that
//!   `python/compile/aot.py` lowered from the Layer-1 Pallas kernels,
//!   compiles them once on the PJRT CPU client, and executes them from the
//!   Rust request path. Python is never involved at run time. Requires the
//!   `xla` cargo feature; the default offline build ships a stub whose
//!   constructor reports the feature as unavailable.
//!
//! Both engines return *squared* distances with ties broken to the lowest
//! centroid index, so they are interchangeable; `engine_parity` integration
//! tests assert the XLA engine matches the native one on random tiles.

pub mod manifest;
pub mod native;
pub mod xla;

use crate::error::Result;
use crate::util::matrix::Matrix;

/// Output of an assign-tile dispatch.
#[derive(Clone, Debug, PartialEq)]
pub struct AssignOut {
    /// Nearest-centroid index per point.
    pub idx: Vec<u32>,
    /// Squared distance to the winner.
    pub best: Vec<f32>,
    /// Squared distance to the runner-up (`inf` when k == 1).
    pub second: Vec<f32>,
}

/// A tile executor.
pub trait Engine {
    fn name(&self) -> &'static str;

    /// Assign every row of `points` to its nearest row of `centroids`.
    fn assign_tile(&mut self, points: &Matrix, centroids: &Matrix) -> Result<AssignOut>;

    /// Execute several independent `(points, centroids)` groups in one
    /// dispatch — the entry point `serve`'s micro-batching scheduler
    /// coalesces compatible requests into, so the engine boundary is
    /// crossed once per iteration for a whole batch instead of once per
    /// request.
    ///
    /// Contract: group `i` of the output is exactly
    /// `assign_tile(groups[i].0, groups[i].1)` — same floats, same
    /// tie-breaks — so batching can never change a clustering. The default
    /// implementation is that loop; engines may override to amortize
    /// per-dispatch setup further, but must preserve per-group numerics.
    fn assign_batch(&mut self, groups: &[(&Matrix, &Matrix)]) -> Result<Vec<AssignOut>> {
        groups.iter().map(|(pts, cents)| self.assign_tile(pts, cents)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn assign_out_equality_semantics() {
        let a = AssignOut { idx: vec![0], best: vec![1.0], second: vec![2.0] };
        let b = a.clone();
        assert_eq!(a, b);
    }

    #[test]
    fn default_assign_batch_matches_per_tile_calls() {
        use crate::data::synth;
        let a = synth::blobs(64, 5, 2, 1);
        let b = synth::blobs(48, 5, 3, 2);
        let ca = a.points.gather_rows(&[0, 7]);
        let cb = b.points.gather_rows(&[1, 5, 9]);
        let mut eng = native::NativeEngine;
        let batched = eng
            .assign_batch(&[(&a.points, &ca), (&b.points, &cb)])
            .unwrap();
        assert_eq!(batched.len(), 2);
        assert_eq!(batched[0], eng.assign_tile(&a.points, &ca).unwrap());
        assert_eq!(batched[1], eng.assign_tile(&b.points, &cb).unwrap());
        let empty = eng.assign_batch(&[]).unwrap();
        assert!(empty.is_empty());
    }
}
