//! The PJRT/XLA engine: AOT-compiled Pallas kernels on the Rust hot path.
//!
//! Load path (see `python/compile/aot.py`): HLO **text** →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `PjRtClient::compile`, once per variant, cached for the life of the
//! engine. Execution builds `Literal`s from the tile, runs the executable
//! and unpacks the 3-tuple (assign, best, second).
//!
//! Padding policy: the exported variants are a fixed grid (see
//! `python/compile/aot.py`); a (d, k) problem runs on the smallest
//! dominating variant. Points/centroids are zero-padded in `d` — zero
//! padding is exact for squared distances when both sides pad with the
//! same constant. `k` is padded with sentinel centroids at [`SENTINEL`]
//! coordinates, far enough that they can never win or place second on
//! normalised data; rows are padded to the tile and sliced off on return.
//!
//! Feature gating: the PJRT client lives in the external `xla` crate, which
//! is not part of the offline crate universe. With the `xla` cargo feature
//! disabled (the default), this module compiles a stub [`XlaEngine`] whose
//! constructor returns [`Error::Xla`](crate::error::Error::Xla) — the
//! coordinator's `Backend::Xla`, the benches and the examples all handle
//! that cleanly and fall back to skipping the XLA path. The padding policy
//! itself is pure and always compiled (and unit-tested) so the AOT contract
//! stays pinned even in stub builds.

use crate::util::matrix::Matrix;

/// Coordinate of sentinel padding centroids. Distances to these are
/// ~`d · (SENTINEL)²` ≈ 1e12 — orders of magnitude beyond any real
/// squared distance on normalised (or even raw UCI-ranged) data.
pub const SENTINEL: f32 = 1.0e6;

/// Pad centroids to (k_pad, d): zero-pad dims, sentinel-pad rows. Pure —
/// compiled in every build so the unit tests pin the padding policy even
/// when the PJRT engine itself is stubbed out.
#[cfg_attr(not(feature = "xla"), allow(dead_code))]
fn pad_centroids_buf(centroids: &Matrix, k_pad: usize, d: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; k_pad * d];
    for (c, row) in centroids.rows_iter().enumerate() {
        out[c * d..c * d + row.len()].copy_from_slice(row);
    }
    for c in centroids.rows()..k_pad {
        for j in 0..d {
            out[c * d + j] = SENTINEL;
        }
    }
    out
}

/// Pad rows `start..end` of `points` into the reusable tile buffer
/// (zero-filled tail). Single copy: rows go straight from the source
/// matrix into the buffer the literal is built from — §Perf shaved the
/// gather-then-pad double copy off the request path.
#[cfg_attr(not(feature = "xla"), allow(dead_code))]
fn fill_tile_buf(buf: &mut [f32], points: &Matrix, start: usize, end: usize, d: usize) {
    let d_real = points.cols();
    buf.fill(0.0);
    for (i, r) in (start..end).enumerate() {
        buf[i * d..i * d + d_real].copy_from_slice(points.row(r));
    }
}

#[cfg(feature = "xla")]
mod pjrt {
    use std::collections::HashMap;
    use std::path::Path;

    use crate::error::{Error, Result};
    use crate::util::matrix::Matrix;

    use super::super::manifest::{ArtifactRecord, Manifest};
    use super::super::{AssignOut, Engine};
    use super::{fill_tile_buf, pad_centroids_buf};

    /// PJRT-backed engine.
    pub struct XlaEngine {
        manifest: Manifest,
        client: xla::PjRtClient,
        /// Compiled executables keyed by artifact name.
        cache: HashMap<String, xla::PjRtLoadedExecutable>,
        /// Executed-tile counter (telemetry).
        pub tiles_executed: u64,
    }

    impl XlaEngine {
        /// Create from an artifact directory (compiles lazily per variant).
        pub fn new(artifact_dir: &Path) -> Result<Self> {
            let manifest = Manifest::load(artifact_dir)?;
            let client = xla::PjRtClient::cpu()?;
            Ok(Self { manifest, client, cache: HashMap::new(), tiles_executed: 0 })
        }

        /// The loaded artifact manifest. Only exists on the real engine —
        /// callers outside `cfg(feature = "xla")` code must not rely on it.
        pub fn manifest(&self) -> &Manifest {
            &self.manifest
        }

        fn executable(&mut self, rec: &ArtifactRecord) -> Result<&xla::PjRtLoadedExecutable> {
            if !self.cache.contains_key(&rec.name) {
                let proto = xla::HloModuleProto::from_text_file(
                    rec.file
                        .to_str()
                        .ok_or_else(|| Error::Artifact("non-utf8 artifact path".into()))?,
                )?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = self.client.compile(&comp)?;
                self.cache.insert(rec.name.clone(), exe);
            }
            Ok(&self.cache[&rec.name])
        }

        /// Build an f32 literal from a slice without the vec1+reshape double
        /// copy (`create_from_shape_and_untyped_data` copies once).
        fn f32_literal(data: &[f32], dims: &[usize]) -> Result<xla::Literal> {
            let bytes = unsafe {
                std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
            };
            xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::F32, dims, bytes)
                .map_err(|e| Error::Xla(e.to_string()))
        }

        /// Execute one padded sub-tile of exactly `tile_n` rows. The centroid
        /// literal is built once per `assign_tile` call and borrowed here —
        /// `execute` accepts `Borrow<Literal>`, so nothing is re-copied per
        /// tile (§Perf).
        fn run_tile(
            &self,
            rec_name: &str,
            x: &xla::Literal,
            c: &xla::Literal,
        ) -> Result<(Vec<i32>, Vec<f32>, Vec<f32>)> {
            let exe = self
                .cache
                .get(rec_name)
                .ok_or_else(|| Error::Artifact(format!("uncompiled artifact {rec_name}")))?;
            let result = exe.execute::<&xla::Literal>(&[x, c])?[0][0].to_literal_sync()?;
            let (idx, best, second) = result.to_tuple3()?;
            Ok((idx.to_vec::<i32>()?, best.to_vec::<f32>()?, second.to_vec::<f32>()?))
        }
    }

    impl Engine for XlaEngine {
        fn name(&self) -> &'static str {
            "xla-pjrt"
        }

        fn assign_tile(&mut self, points: &Matrix, centroids: &Matrix) -> Result<AssignOut> {
            let (n, d_real) = (points.rows(), points.cols());
            let k_real = centroids.rows();
            if centroids.cols() != d_real {
                return Err(Error::Config(format!(
                    "points d={} vs centroids d={}",
                    d_real,
                    centroids.cols()
                )));
            }
            let rec = self.manifest.pick_assign(d_real, k_real)?.clone();
            let (tile_n, d, k_pad) = (rec.tile_n, rec.d, rec.k);
            self.executable(&rec)?;
            let cents = pad_centroids_buf(centroids, k_pad, d);
            let c_lit = Self::f32_literal(&cents, &[k_pad, d])?;
            let mut tile_buf = vec![0.0f32; tile_n * d];

            let mut idx = Vec::with_capacity(n);
            let mut best = Vec::with_capacity(n);
            let mut second = Vec::with_capacity(n);
            let mut start = 0usize;
            while start < n {
                let end = (start + tile_n).min(n);
                fill_tile_buf(&mut tile_buf, points, start, end, d);
                let x_lit = Self::f32_literal(&tile_buf, &[tile_n, d])?;
                let (ti, tb, ts) = self.run_tile(&rec.name, &x_lit, &c_lit)?;
                let rows = end - start;
                idx.extend(ti[..rows].iter().map(|&v| v as u32));
                best.extend_from_slice(&tb[..rows]);
                // If k was padded, a sentinel can only appear as runner-up
                // for k_real == 1; restore the exact semantics (inf).
                if k_real == 1 {
                    second.extend(std::iter::repeat(f32::INFINITY).take(rows));
                } else {
                    second.extend_from_slice(&ts[..rows]);
                }
                self.tiles_executed += 1;
                start = end;
            }
            Ok(AssignOut { idx, best, second })
        }
    }
}

#[cfg(not(feature = "xla"))]
mod stub {
    use std::path::Path;

    use crate::error::{Error, Result};
    use crate::util::matrix::Matrix;

    use super::super::{AssignOut, Engine};

    /// Stub engine compiled when the `xla` feature is off: the constructor
    /// fails with a descriptive error, so every caller (coordinator,
    /// benches, examples) takes its "XLA unavailable" branch. It mirrors
    /// the surface those callers use — `new`, `tiles_executed` and the
    /// [`Engine`] impl (`manifest()` is xla-only) — so no caller needs its
    /// own cfg.
    pub struct XlaEngine {
        /// Executed-tile counter (always 0 in the stub).
        pub tiles_executed: u64,
    }

    impl XlaEngine {
        /// Always fails: this build has no PJRT client.
        pub fn new(_artifact_dir: &Path) -> Result<Self> {
            Err(Error::Xla(
                "built without the `xla` cargo feature (PJRT client unavailable in the \
                 offline crate universe); use the fpga-sim or native backend"
                    .into(),
            ))
        }
    }

    impl Engine for XlaEngine {
        fn name(&self) -> &'static str {
            "xla-pjrt"
        }

        fn assign_tile(&mut self, _points: &Matrix, _centroids: &Matrix) -> Result<AssignOut> {
            Err(Error::Xla("xla feature not enabled".into()))
        }
    }
}

#[cfg(feature = "xla")]
pub use pjrt::XlaEngine;
#[cfg(not(feature = "xla"))]
pub use stub::XlaEngine;

#[cfg(test)]
mod tests {
    // The full XLA engine needs built artifacts + the `xla` feature; its
    // behaviour is covered by the `engine_parity` integration test. Unit
    // tests here cover the pure padding helpers, which both engine builds
    // share, and the stub's error contract.
    use super::*;

    #[test]
    fn pad_centroids_sentinel_rows_and_zero_dims() {
        let m = Matrix::from_vec(vec![1.0, 2.0], 1, 2).unwrap();
        let c = pad_centroids_buf(&m, 3, 3);
        assert_eq!(&c[0..3], &[1.0, 2.0, 0.0], "real rows zero-pad in d");
        assert!(c[3..].iter().all(|&v| v == SENTINEL), "padded rows are sentinels");
    }

    #[test]
    fn fill_tile_reuses_buffer_and_zero_fills() {
        let m = Matrix::from_vec(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0], 3, 2).unwrap();
        let mut buf = vec![9.0f32; 4 * 3]; // stale contents must be cleared
        fill_tile_buf(&mut buf, &m, 1, 3, 3);
        assert_eq!(&buf[0..3], &[3.0, 4.0, 0.0]);
        assert_eq!(&buf[3..6], &[5.0, 6.0, 0.0]);
        assert!(buf[6..].iter().all(|&v| v == 0.0));
    }

    #[cfg(not(feature = "xla"))]
    #[test]
    fn stub_constructor_reports_missing_feature() {
        let err = XlaEngine::new(std::path::Path::new("artifacts")).unwrap_err();
        assert!(err.to_string().contains("xla"), "{err}");
    }
}
