//! The PJRT/XLA engine: AOT-compiled Pallas kernels on the Rust hot path.
//!
//! Load path (see /opt/xla-example/load_hlo and aot.py): HLO **text** →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `PjRtClient::compile`, once per variant, cached for the life of the
//! engine. Execution builds `Literal`s from the tile, runs the executable
//! and unpacks the 3-tuple (assign, best, second).
//!
//! Padding policy: the exported variants are a fixed grid (see
//! `python/compile/aot.py`); a (d, k) problem runs on the smallest
//! dominating variant. Points/centroids are zero-padded in `d` — zero
//! padding is exact for squared distances when both sides pad with the
//! same constant. `k` is padded with sentinel centroids at `SENTINEL`
//! coordinates, far enough that they can never win or place second on
//! normalised data; rows are padded to the tile and sliced off on return.

use std::collections::HashMap;
use std::path::Path;

use crate::error::{Error, Result};
use crate::util::matrix::Matrix;

use super::manifest::{ArtifactRecord, Manifest};
use super::{AssignOut, Engine};

/// Coordinate of sentinel padding centroids. Distances to these are
/// ~`d · (SENTINEL)²` ≈ 1e12 — orders of magnitude beyond any real
/// squared distance on normalised (or even raw UCI-ranged) data.
pub const SENTINEL: f32 = 1.0e6;

/// PJRT-backed engine.
pub struct XlaEngine {
    manifest: Manifest,
    client: xla::PjRtClient,
    /// Compiled executables keyed by artifact name.
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
    /// Executed-tile counter (telemetry).
    pub tiles_executed: u64,
}

impl XlaEngine {
    /// Create from an artifact directory (compiles lazily per variant).
    pub fn new(artifact_dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(artifact_dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Self { manifest, client, cache: HashMap::new(), tiles_executed: 0 })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn executable(&mut self, rec: &ArtifactRecord) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.cache.contains_key(&rec.name) {
            let proto = xla::HloModuleProto::from_text_file(
                rec.file
                    .to_str()
                    .ok_or_else(|| Error::Artifact("non-utf8 artifact path".into()))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            self.cache.insert(rec.name.clone(), exe);
        }
        Ok(&self.cache[&rec.name])
    }

    /// Pad a tile to the variant's (tile_n, d) with zeros.
    fn pad_points(points: &Matrix, tile_n: usize, d: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; tile_n * d];
        for (i, row) in points.rows_iter().enumerate() {
            out[i * d..i * d + row.len()].copy_from_slice(row);
        }
        out
    }

    /// Pad rows `start..end` of `points` into the reusable tile buffer
    /// (zero-filled tail). Single copy: rows go straight from the source
    /// matrix into the buffer the literal is built from — §Perf shaved the
    /// gather-then-pad double copy off the request path.
    fn fill_tile(buf: &mut [f32], points: &Matrix, start: usize, end: usize, d: usize) {
        let d_real = points.cols();
        buf.fill(0.0);
        for (i, r) in (start..end).enumerate() {
            buf[i * d..i * d + d_real].copy_from_slice(points.row(r));
        }
    }

    /// Build an f32 literal from a slice without the vec1+reshape double
    /// copy (`create_from_shape_and_untyped_data` copies once).
    fn f32_literal(data: &[f32], dims: &[usize]) -> Result<xla::Literal> {
        let bytes = unsafe {
            std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
        };
        xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::F32, dims, bytes)
            .map_err(|e| Error::Xla(e.to_string()))
    }

    /// Pad centroids to (k_pad, d): zero-pad dims, sentinel-pad rows.
    fn pad_centroids(centroids: &Matrix, k_pad: usize, d: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; k_pad * d];
        for (c, row) in centroids.rows_iter().enumerate() {
            out[c * d..c * d + row.len()].copy_from_slice(row);
        }
        for c in centroids.rows()..k_pad {
            for j in 0..d {
                out[c * d + j] = SENTINEL;
            }
        }
        out
    }

    /// Execute one padded sub-tile of exactly `tile_n` rows. The centroid
    /// literal is built once per `assign_tile` call and borrowed here —
    /// `execute` accepts `Borrow<Literal>`, so nothing is re-copied per
    /// tile (§Perf).
    fn run_tile(
        &self,
        rec_name: &str,
        x: &xla::Literal,
        c: &xla::Literal,
    ) -> Result<(Vec<i32>, Vec<f32>, Vec<f32>)> {
        let exe = self
            .cache
            .get(rec_name)
            .ok_or_else(|| Error::Artifact(format!("uncompiled artifact {rec_name}")))?;
        let result = exe.execute::<&xla::Literal>(&[x, c])?[0][0].to_literal_sync()?;
        let (idx, best, second) = result.to_tuple3()?;
        Ok((idx.to_vec::<i32>()?, best.to_vec::<f32>()?, second.to_vec::<f32>()?))
    }
}

impl Engine for XlaEngine {
    fn name(&self) -> &'static str {
        "xla-pjrt"
    }

    fn assign_tile(&mut self, points: &Matrix, centroids: &Matrix) -> Result<AssignOut> {
        let (n, d_real) = (points.rows(), points.cols());
        let k_real = centroids.rows();
        if centroids.cols() != d_real {
            return Err(Error::Config(format!(
                "points d={} vs centroids d={}",
                d_real,
                centroids.cols()
            )));
        }
        let rec = self.manifest.pick_assign(d_real, k_real)?.clone();
        let (tile_n, d, k_pad) = (rec.tile_n, rec.d, rec.k);
        self.executable(&rec)?;
        let cents = Self::pad_centroids(centroids, k_pad, d);
        let c_lit = Self::f32_literal(&cents, &[k_pad, d])?;
        let mut tile_buf = vec![0.0f32; tile_n * d];

        let mut idx = Vec::with_capacity(n);
        let mut best = Vec::with_capacity(n);
        let mut second = Vec::with_capacity(n);
        let mut start = 0usize;
        while start < n {
            let end = (start + tile_n).min(n);
            Self::fill_tile(&mut tile_buf, points, start, end, d);
            let x_lit = Self::f32_literal(&tile_buf, &[tile_n, d])?;
            let (ti, tb, ts) = self.run_tile(&rec.name, &x_lit, &c_lit)?;
            let rows = end - start;
            idx.extend(ti[..rows].iter().map(|&v| v as u32));
            best.extend_from_slice(&tb[..rows]);
            // If k was padded, a sentinel can only appear as runner-up for
            // k_real == 1; restore the exact semantics (inf).
            if k_real == 1 {
                second.extend(std::iter::repeat(f32::INFINITY).take(rows));
            } else {
                second.extend_from_slice(&ts[..rows]);
            }
            self.tiles_executed += 1;
            start = end;
        }
        Ok(AssignOut { idx, best, second })
    }
}

#[cfg(test)]
mod tests {
    // The XLA engine needs built artifacts; its behaviour is covered by the
    // `engine_parity` integration test (rust/tests/), which `make test`
    // runs after `make artifacts`. Unit tests here cover the pure helpers.
    use super::*;

    #[test]
    fn pad_points_zero_fills() {
        let m = Matrix::from_vec(vec![1.0, 2.0, 3.0, 4.0], 2, 2).unwrap();
        let p = XlaEngine::pad_points(&m, 4, 3);
        assert_eq!(p.len(), 12);
        assert_eq!(&p[0..3], &[1.0, 2.0, 0.0]);
        assert_eq!(&p[3..6], &[3.0, 4.0, 0.0]);
        assert!(p[6..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn pad_centroids_sentinel_rows() {
        let m = Matrix::from_vec(vec![1.0, 2.0], 1, 2).unwrap();
        let c = XlaEngine::pad_centroids(&m, 3, 2);
        assert_eq!(&c[0..2], &[1.0, 2.0]);
        assert!(c[2..].iter().all(|&v| v == SENTINEL));
    }
}
