//! Run configuration: the launcher's surface.
//!
//! A [`RunConfig`] fully describes one clustering job — dataset, algorithm
//! parameters, backend and accelerator geometry — and can be loaded from a
//! TOML file (subset grammar, `util::toml`) or built programmatically.
//! `kpynq init-config` prints [`EXAMPLE`] as a starting point.

use std::path::{Path, PathBuf};

use crate::coordinator::Backend;
use crate::error::{Error, Result};
use crate::hw::{AccelConfig, ZynqPart};
use crate::kmeans::{Algorithm, InitMethod, KMeansConfig};
use crate::serve::{NetConfig, ServeConfig, ShedPolicy};
use crate::util::toml;

/// Dimensionality of the `blobs`/`uniform` generator datasets
/// ([`RunConfig::load_dataset`]). `serve::batch::dataset_dim` keys batch
/// compatibility on this same constant — change it in one place only.
pub const SYNTH_DEFAULT_DIM: usize = 16;

/// A complete run description.
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// Dataset name: one of the six UCI-equivalents, `blobs`, `uniform`,
    /// or a path to a `.kpm` / `.csv` file.
    pub dataset: String,
    /// Generator seed (synthetic datasets).
    pub data_seed: u64,
    /// Subsample cap (0 = use everything).
    pub max_points: usize,
    /// Normalisation: "minmax", "zscore" or "none".
    pub normalize: String,
    /// Which software algorithm `kpynq run --software` uses.
    pub algorithm: Algorithm,
    pub kmeans: KMeansConfig,
    /// Backend: "fpga-sim", "native" or "xla".
    pub backend_name: String,
    pub artifact_dir: PathBuf,
    /// Accelerator geometry (fpga-sim backend).
    pub lanes: u64,
    pub mac_width: u64,
    pub tile_points: usize,
    pub enable_filters: bool,
    /// Part: "xc7z020" or "zu7ev".
    pub part: String,
    /// Serving pool: worker shard count (`kpynq serve`).
    pub serve_workers: usize,
    /// Serving pool: admission queue capacity.
    pub serve_queue_capacity: usize,
    /// Serving pool: micro-batch cap (1 = no coalescing).
    pub serve_max_batch: usize,
    /// Serving pool: full-queue policy, "block" or "shed".
    pub serve_shed: String,
    /// Serving pool: per-tenant weighted-fair weights, as raw
    /// `"tenant=weight"` entries (PROTOCOL.md §7).
    pub serve_tenant_weights: Vec<String>,
    /// Serving pool: weight for tenants not listed in `tenant_weights`.
    pub serve_default_tenant_weight: usize,
    /// Serving pool: max queued jobs per tenant (0 = no per-tenant quota).
    pub serve_tenant_queue_cap: usize,
    /// Serving pool: result-cache entries (0 = caching off, PROTOCOL.md §8).
    pub serve_cache_capacity: usize,
    /// Serving pool: distinct tenants tracked before overflow rolls into
    /// the `~other` bucket (PROTOCOL.md §3).
    pub serve_max_tracked_tenants: usize,
    /// Daemon listener: `host:port`, `unix:<path>`, or "" for one-shot
    /// stdin mode (`kpynq serve --listen` overrides).
    pub serve_listen: String,
    /// Daemon: simultaneous-connection cap.
    pub serve_max_conns: usize,
    /// Daemon: idle-connection timeout in milliseconds (0 = never).
    pub serve_idle_timeout_ms: u64,
    /// Daemon: tee drained trace spans to this JSONL file ("" = off).
    pub serve_trace_log: String,
    /// Daemon: also serve `GET /metrics` (Prometheus text 0.0.4) on this
    /// `host:port` ("" = off). PROTOCOL.md §11.
    pub serve_metrics_listen: String,
    /// Enable the per-phase solver timers (`obs::profile`): replies gain
    /// the `phase_*_ms` keys. Provably non-perturbing (DESIGN.md §2).
    pub profile: bool,
    /// Cluster: shard daemon count (`kpynq cluster`).
    pub cluster_shards: usize,
    /// Cluster: directory for shard `unix:` sockets ("" = per-process
    /// temp dir).
    pub cluster_socket_dir: String,
    /// Cluster: respawns (local) / reconnects (remote) allowed per shard
    /// before it is abandoned.
    pub cluster_max_restarts: usize,
    /// Cluster remote mode: addresses of already-running daemons to
    /// attach to instead of spawning local shards (empty = local mode).
    pub cluster_remote_shards: Vec<String>,
    /// Cluster: link (re)connect attempts per loss.
    pub cluster_reconnect_attempts: usize,
    /// Cluster: first retry delay in milliseconds (doubles per attempt).
    pub cluster_reconnect_base_ms: u64,
    /// Cluster: backoff delay cap in milliseconds.
    pub cluster_reconnect_cap_ms: u64,
    /// Cluster: hard bound on total backoff sleep per (re)connect, ms.
    pub cluster_reconnect_total_wait_ms: u64,
    /// Cluster: how jobs map onto shards — "request" routes each job
    /// whole to one shard, "map-reduce" slices every job's points across
    /// all shards (PROTOCOL.md §10).
    pub cluster_fit_mode: String,
}

impl Default for RunConfig {
    fn default() -> Self {
        let accel = AccelConfig::default();
        Self {
            dataset: "blobs".into(),
            data_seed: 0xC0FFEE,
            max_points: 0,
            normalize: "minmax".into(),
            algorithm: Algorithm::Yinyang,
            kmeans: KMeansConfig::default(),
            backend_name: "fpga-sim".into(),
            artifact_dir: PathBuf::from("artifacts"),
            lanes: accel.pipeline.lanes,
            mac_width: accel.pipeline.mac_width,
            tile_points: accel.tile_points,
            enable_filters: true,
            part: "xc7z020".into(),
            serve_workers: 2,
            serve_queue_capacity: 64,
            serve_max_batch: 8,
            serve_shed: "block".into(),
            serve_tenant_weights: Vec::new(),
            serve_default_tenant_weight: 1,
            serve_tenant_queue_cap: 0,
            serve_cache_capacity: 64,
            serve_max_tracked_tenants: 64,
            serve_listen: String::new(),
            serve_max_conns: 32,
            serve_idle_timeout_ms: 0,
            serve_trace_log: String::new(),
            serve_metrics_listen: String::new(),
            profile: false,
            cluster_shards: 2,
            cluster_socket_dir: String::new(),
            cluster_max_restarts: 3,
            cluster_remote_shards: Vec::new(),
            cluster_reconnect_attempts: 45,
            cluster_reconnect_base_ms: 20,
            cluster_reconnect_cap_ms: 250,
            cluster_reconnect_total_wait_ms: 10_000,
            cluster_fit_mode: "request".into(),
        }
    }
}

/// Example config printed by `kpynq init-config`.
pub const EXAMPLE: &str = r#"# KPynq run configuration
dataset = "kegg"        # gassensor|kegg|roadnetwork|uscensus|covtype|mnist|blobs|uniform|<file>
data_seed = 12648430
max_points = 0           # 0 = full dataset
normalize = "minmax"     # minmax|zscore|none
profile = false          # per-phase solver timers; replies gain phase_*_ms keys

[kmeans]
k = 16
groups = 0               # 0 = auto (ceil(k/10))
max_iters = 100
tol = 1e-4
seed = 12648430
init = "kmeans++"        # kmeans++|random
algorithm = "yinyang"    # lloyd|hamerly|elkan|yinyang (software runs)

[backend]
name = "fpga-sim"        # fpga-sim|native|xla
artifact_dir = "artifacts"

[accelerator]
lanes = 4
mac_width = 4
tile_points = 256
enable_filters = true
part = "xc7z020"         # xc7z020|zu7ev

[serve]
workers = 2              # worker shards (kpynq serve)
queue_capacity = 64      # bounded admission queue
max_batch = 8            # micro-batch cap (1 = no coalescing)
shed = "block"           # block|shed (full-queue policy)
tenant_weights = []      # weighted-fair scheduling: ["acme=3", "free=1"]
default_tenant_weight = 1  # weight for tenants not listed above
tenant_queue_cap = 0     # max queued jobs per tenant (0 = no quota)
cache_capacity = 64      # result-cache entries (0 = caching off)
max_tracked_tenants = 64 # distinct tenants tracked before ~other overflow

[serve.net]
listen = ""              # daemon: "host:port" or "unix:/path.sock"; "" = one-shot stdin mode
max_conns = 32           # simultaneous client connections (extras refused)
idle_timeout_ms = 0      # close idle connections after this long (0 = never)
trace_log = ""           # tee drained trace spans to this JSONL file ("" = off)
metrics_listen = ""      # serve GET /metrics (Prometheus text 0.0.4) on "host:port" ("" = off)

[cluster]
shards = 2               # shard daemon processes (kpynq cluster); each gets the [serve] pool
socket_dir = ""          # shard unix-socket dir; "" = per-process temp dir
max_restarts = 3         # respawns (local) / reconnects (remote) per shard before abandoning it
remote_shards = []       # remote mode: ["hosta:7071", "unix:/path.sock"] — attach, don't spawn
reconnect_attempts = 45  # link (re)connect attempts per loss
reconnect_base_ms = 20   # first retry delay (doubles per attempt)
reconnect_cap_ms = 250   # backoff delay cap
reconnect_total_wait_ms = 10000  # hard bound on total backoff sleep per (re)connect
fit_mode = "request"     # request (route each job to one shard) | map-reduce (slice each job across all shards)
"#;

impl RunConfig {
    /// Load from a TOML-subset file.
    pub fn from_file(path: &Path) -> Result<RunConfig> {
        let text = std::fs::read_to_string(path)?;
        Self::from_toml(&text)
    }

    /// Parse from TOML-subset text.
    pub fn from_toml(text: &str) -> Result<RunConfig> {
        let doc = toml::parse(text)?;
        let mut cfg = RunConfig::default();

        if let Some(v) = toml::get(&doc, "", "dataset") {
            cfg.dataset = v.as_str()?.to_string();
        }
        if let Some(v) = toml::get(&doc, "", "data_seed") {
            cfg.data_seed = v.as_i64()? as u64;
        }
        if let Some(v) = toml::get(&doc, "", "max_points") {
            cfg.max_points = v.as_usize()?;
        }
        if let Some(v) = toml::get(&doc, "", "normalize") {
            cfg.normalize = v.as_str()?.to_string();
        }
        if let Some(v) = toml::get(&doc, "", "profile") {
            cfg.profile = v.as_bool()?;
        }

        if let Some(v) = toml::get(&doc, "kmeans", "k") {
            cfg.kmeans.k = v.as_usize()?;
        }
        if let Some(v) = toml::get(&doc, "kmeans", "groups") {
            cfg.kmeans.groups = v.as_usize()?;
        }
        if let Some(v) = toml::get(&doc, "kmeans", "max_iters") {
            cfg.kmeans.max_iters = v.as_usize()?;
        }
        if let Some(v) = toml::get(&doc, "kmeans", "tol") {
            cfg.kmeans.tol = v.as_f64()?;
        }
        if let Some(v) = toml::get(&doc, "kmeans", "seed") {
            cfg.kmeans.seed = v.as_i64()? as u64;
        }
        if let Some(v) = toml::get(&doc, "kmeans", "init") {
            cfg.kmeans.init = match v.as_str()? {
                "kmeans++" => InitMethod::KMeansPlusPlus,
                "random" => InitMethod::RandomPoints,
                other => {
                    return Err(Error::Config(format!("unknown init '{other}'")));
                }
            };
        }
        if let Some(v) = toml::get(&doc, "kmeans", "algorithm") {
            cfg.algorithm = Algorithm::from_name(v.as_str()?)?;
        }

        if let Some(v) = toml::get(&doc, "backend", "name") {
            cfg.backend_name = v.as_str()?.to_string();
        }
        if let Some(v) = toml::get(&doc, "backend", "artifact_dir") {
            cfg.artifact_dir = PathBuf::from(v.as_str()?);
        }

        if let Some(v) = toml::get(&doc, "accelerator", "lanes") {
            cfg.lanes = v.as_i64()? as u64;
        }
        if let Some(v) = toml::get(&doc, "accelerator", "mac_width") {
            cfg.mac_width = v.as_i64()? as u64;
        }
        if let Some(v) = toml::get(&doc, "accelerator", "tile_points") {
            cfg.tile_points = v.as_usize()?;
        }
        if let Some(v) = toml::get(&doc, "accelerator", "enable_filters") {
            cfg.enable_filters = v.as_bool()?;
        }
        if let Some(v) = toml::get(&doc, "accelerator", "part") {
            cfg.part = v.as_str()?.to_string();
        }

        if let Some(v) = toml::get(&doc, "serve", "workers") {
            cfg.serve_workers = v.as_usize()?;
        }
        if let Some(v) = toml::get(&doc, "serve", "queue_capacity") {
            cfg.serve_queue_capacity = v.as_usize()?;
        }
        if let Some(v) = toml::get(&doc, "serve", "max_batch") {
            cfg.serve_max_batch = v.as_usize()?;
        }
        if let Some(v) = toml::get(&doc, "serve", "shed") {
            cfg.serve_shed = v.as_str()?.to_string();
        }
        if let Some(v) = toml::get(&doc, "serve", "tenant_weights") {
            cfg.serve_tenant_weights = match v {
                toml::Value::Arr(items) => items
                    .iter()
                    .map(|item| Ok(item.as_str()?.to_string()))
                    .collect::<Result<Vec<String>>>()?,
                other => {
                    return Err(Error::Config(format!(
                        "serve tenant_weights must be an array of \"tenant=weight\" strings, \
                         got {other:?}"
                    )))
                }
            };
        }
        if let Some(v) = toml::get(&doc, "serve", "default_tenant_weight") {
            cfg.serve_default_tenant_weight = v.as_usize()?;
        }
        if let Some(v) = toml::get(&doc, "serve", "tenant_queue_cap") {
            cfg.serve_tenant_queue_cap = v.as_usize()?;
        }
        if let Some(v) = toml::get(&doc, "serve", "cache_capacity") {
            cfg.serve_cache_capacity = v.as_usize()?;
        }
        if let Some(v) = toml::get(&doc, "serve", "max_tracked_tenants") {
            cfg.serve_max_tracked_tenants = v.as_usize()?;
        }

        if let Some(v) = toml::get(&doc, "serve.net", "listen") {
            cfg.serve_listen = v.as_str()?.to_string();
        }
        if let Some(v) = toml::get(&doc, "serve.net", "max_conns") {
            cfg.serve_max_conns = v.as_usize()?;
        }
        if let Some(v) = toml::get(&doc, "serve.net", "idle_timeout_ms") {
            // as_usize rejects negatives; `-500` must error, not wrap to
            // a ~584-million-year timeout.
            cfg.serve_idle_timeout_ms = v.as_usize()? as u64;
        }
        if let Some(v) = toml::get(&doc, "serve.net", "trace_log") {
            cfg.serve_trace_log = v.as_str()?.to_string();
        }
        if let Some(v) = toml::get(&doc, "serve.net", "metrics_listen") {
            cfg.serve_metrics_listen = v.as_str()?.to_string();
        }

        if let Some(v) = toml::get(&doc, "cluster", "shards") {
            cfg.cluster_shards = v.as_usize()?;
        }
        if let Some(v) = toml::get(&doc, "cluster", "socket_dir") {
            cfg.cluster_socket_dir = v.as_str()?.to_string();
        }
        if let Some(v) = toml::get(&doc, "cluster", "max_restarts") {
            cfg.cluster_max_restarts = v.as_usize()?;
        }
        if let Some(v) = toml::get(&doc, "cluster", "remote_shards") {
            cfg.cluster_remote_shards = match v {
                toml::Value::Arr(items) => items
                    .iter()
                    .map(|item| Ok(item.as_str()?.to_string()))
                    .collect::<Result<Vec<String>>>()?,
                other => {
                    return Err(Error::Config(format!(
                        "cluster remote_shards must be an array of address strings, got {other:?}"
                    )))
                }
            };
        }
        if let Some(v) = toml::get(&doc, "cluster", "reconnect_attempts") {
            cfg.cluster_reconnect_attempts = v.as_usize()?;
        }
        if let Some(v) = toml::get(&doc, "cluster", "reconnect_base_ms") {
            cfg.cluster_reconnect_base_ms = v.as_usize()? as u64;
        }
        if let Some(v) = toml::get(&doc, "cluster", "reconnect_cap_ms") {
            cfg.cluster_reconnect_cap_ms = v.as_usize()? as u64;
        }
        if let Some(v) = toml::get(&doc, "cluster", "reconnect_total_wait_ms") {
            cfg.cluster_reconnect_total_wait_ms = v.as_usize()? as u64;
        }
        if let Some(v) = toml::get(&doc, "cluster", "fit_mode") {
            cfg.cluster_fit_mode = v.as_str()?.to_string();
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<()> {
        match self.normalize.as_str() {
            "minmax" | "zscore" | "none" => {}
            other => return Err(Error::Config(format!("unknown normalize '{other}'"))),
        }
        match self.backend_name.as_str() {
            "fpga-sim" | "native" | "xla" => {}
            other => return Err(Error::Config(format!("unknown backend '{other}'"))),
        }
        match self.part.as_str() {
            "xc7z020" | "zu7ev" => {}
            other => return Err(Error::Config(format!("unknown part '{other}'"))),
        }
        if self.lanes == 0 || self.mac_width == 0 || self.tile_points == 0 {
            return Err(Error::Config("lanes/mac_width/tile_points must be positive".into()));
        }
        self.serve_config()?;
        self.net_config()?;
        self.cluster_config()?;
        Ok(())
    }

    /// Build the cluster shape described by the `[cluster]` section (the
    /// per-shard pool comes from `[serve]`; the shard binary defaults to
    /// the current executable). A non-empty `remote_shards` selects
    /// remote mode — attach to those daemons instead of spawning local
    /// children — with the `reconnect_*` keys shaping the shared
    /// `ReconnectPolicy`.
    pub fn cluster_config(&self) -> Result<crate::cluster::ClusterConfig> {
        use std::time::Duration;
        let cfg = crate::cluster::ClusterConfig {
            shards: self.cluster_shards,
            remote_shards: self.cluster_remote_shards.clone(),
            reconnect: crate::cluster::ReconnectPolicy {
                attempts: self.cluster_reconnect_attempts as u32,
                base_delay: Duration::from_millis(self.cluster_reconnect_base_ms),
                max_delay: Duration::from_millis(self.cluster_reconnect_cap_ms),
                total_wait: Duration::from_millis(self.cluster_reconnect_total_wait_ms),
            },
            serve: self.serve_config()?,
            socket_dir: if self.cluster_socket_dir.is_empty() {
                crate::cluster::default_socket_dir()
            } else {
                PathBuf::from(&self.cluster_socket_dir)
            },
            max_restarts: self.cluster_max_restarts as u32,
            fit_mode: crate::cluster::FitMode::from_name(&self.cluster_fit_mode)?,
            ..Default::default()
        };
        cfg.validate()?;
        Ok(cfg)
    }

    /// Build the serving-pool config described by the `[serve]` section.
    pub fn serve_config(&self) -> Result<ServeConfig> {
        let cfg = ServeConfig {
            workers: self.serve_workers,
            queue_capacity: self.serve_queue_capacity,
            max_batch: self.serve_max_batch,
            shed_policy: ShedPolicy::from_name(&self.serve_shed)?,
            tenant_weights: ServeConfig::parse_tenant_weights(&self.serve_tenant_weights)?,
            default_tenant_weight: self.serve_default_tenant_weight as u32,
            tenant_queue_cap: self.serve_tenant_queue_cap,
            cache_capacity: self.serve_cache_capacity,
            max_tracked_tenants: self.serve_max_tracked_tenants,
        };
        cfg.validate()?;
        Ok(cfg)
    }

    /// Build the daemon listener config described by the `[serve.net]`
    /// section (the address itself lives in `serve_listen`).
    pub fn net_config(&self) -> Result<NetConfig> {
        let cfg = NetConfig {
            max_conns: self.serve_max_conns,
            idle_timeout_ms: self.serve_idle_timeout_ms,
            trace_log: (!self.serve_trace_log.is_empty()).then(|| self.serve_trace_log.clone()),
            metrics_listen: (!self.serve_metrics_listen.is_empty())
                .then(|| self.serve_metrics_listen.clone()),
        };
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn part(&self) -> ZynqPart {
        match self.part.as_str() {
            "zu7ev" => ZynqPart::zu7ev(),
            _ => ZynqPart::xc7z020(),
        }
    }

    /// Build the accelerator config described by this run config.
    pub fn accel_config(&self) -> AccelConfig {
        AccelConfig {
            pipeline: crate::hw::pipeline::PipelineConfig {
                lanes: self.lanes,
                mac_width: self.mac_width,
            },
            tile_points: self.tile_points,
            enable_filters: self.enable_filters,
            part: self.part(),
            ..Default::default()
        }
    }

    /// Build the system backend described by this run config.
    pub fn backend(&self) -> Backend {
        match self.backend_name.as_str() {
            "native" => Backend::Native,
            "xla" => Backend::Xla { artifact_dir: self.artifact_dir.clone() },
            _ => Backend::SimulatedFpga(Box::new(self.accel_config())),
        }
    }

    /// Materialise the dataset this config names.
    pub fn load_dataset(&self) -> Result<crate::data::Dataset> {
        use crate::data::{io, normalize, synth, Dataset};
        let mut ds: Dataset = if let Some(d) = synth::uci(&self.dataset, self.data_seed) {
            d
        } else if self.dataset == "blobs" {
            synth::blobs(20_000, SYNTH_DEFAULT_DIM, self.kmeans.k.max(2), self.data_seed)
        } else if self.dataset == "uniform" {
            synth::uniform(20_000, SYNTH_DEFAULT_DIM, self.data_seed)
        } else {
            let path = Path::new(&self.dataset);
            match path.extension().and_then(|e| e.to_str()) {
                Some("kpm") => io::load("file", path)?,
                Some("csv") => io::read_csv("file", path, true)?,
                _ => {
                    return Err(Error::Data(format!(
                        "unknown dataset '{}' (not a generator, .kpm or .csv)",
                        self.dataset
                    )))
                }
            }
        };
        if self.max_points > 0 {
            ds = ds.subsample(self.max_points, self.data_seed);
        }
        match self.normalize.as_str() {
            "minmax" => normalize::min_max(&mut ds),
            "zscore" => normalize::z_score(&mut ds),
            _ => {}
        }
        ds.validate()?;
        Ok(ds)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn example_config_parses() {
        let cfg = RunConfig::from_toml(EXAMPLE).unwrap();
        assert_eq!(cfg.dataset, "kegg");
        assert_eq!(cfg.kmeans.k, 16);
        assert_eq!(cfg.algorithm, Algorithm::Yinyang);
        assert_eq!(cfg.backend_name, "fpga-sim");
        assert_eq!(cfg.lanes, 4);
        assert!(cfg.enable_filters);
        let serve = cfg.serve_config().unwrap();
        assert_eq!(serve.workers, 2);
        assert_eq!(serve.queue_capacity, 64);
        assert_eq!(serve.max_batch, 8);
        assert_eq!(serve.shed_policy, crate::serve::ShedPolicy::Block);
        assert!(!cfg.profile, "example keeps profiling timers off");
        let net = cfg.net_config().unwrap();
        assert!(net.trace_log.is_none(), "empty string means no trace tee");
        assert!(net.metrics_listen.is_none(), "empty string means no scrape endpoint");
    }

    #[test]
    fn defaults_are_valid() {
        RunConfig::default().validate().unwrap();
    }

    #[test]
    fn rejects_bad_values() {
        assert!(RunConfig::from_toml("normalize = \"bogus\"").is_err());
        assert!(RunConfig::from_toml("[backend]\nname = \"gpu\"").is_err());
        assert!(RunConfig::from_toml("[kmeans]\ninit = \"fancy\"").is_err());
        assert!(RunConfig::from_toml("[accelerator]\nlanes = 0").is_err());
        assert!(RunConfig::from_toml("[serve]\nshed = \"drop\"").is_err());
        assert!(RunConfig::from_toml("[serve]\nworkers = 0").is_err());
        assert!(RunConfig::from_toml("[serve.net]\nmax_conns = 0").is_err());
        assert!(RunConfig::from_toml("[serve.net]\nidle_timeout_ms = -500").is_err());
        assert!(RunConfig::from_toml("[cluster]\nshards = 0").is_err());
    }

    #[test]
    fn cluster_section_configures_the_shard_fleet() {
        let cfg = RunConfig::from_toml(
            "[serve]\nworkers = 3\n[cluster]\nshards = 4\nsocket_dir = \"/tmp/kp\"\nmax_restarts = 1",
        )
        .unwrap();
        let cluster = cfg.cluster_config().unwrap();
        assert_eq!(cluster.shards, 4);
        assert_eq!(cluster.serve.workers, 3, "shards inherit the [serve] pool shape");
        assert_eq!(cluster.socket_dir, PathBuf::from("/tmp/kp"));
        assert_eq!(cluster.max_restarts, 1);
        // Defaults: 2 shards, per-process temp socket dir, local mode,
        // the supervisor's readiness-shaped reconnect policy.
        let d = RunConfig::default().cluster_config().unwrap();
        assert_eq!(d.shards, 2);
        assert!(d.socket_dir.to_string_lossy().contains("kpynq-cluster-"));
        assert!(d.remote_shards.is_empty());
        assert_eq!(d.reconnect, crate::cluster::ReconnectPolicy::default());
    }

    #[test]
    fn cluster_remote_shards_and_reconnect_knobs_parse() {
        let cfg = RunConfig::from_toml(
            "[cluster]\nremote_shards = [\"hosta:7071\", \"unix:/tmp/b.sock\"]\n\
             reconnect_attempts = 5\nreconnect_base_ms = 10\nreconnect_cap_ms = 80\n\
             reconnect_total_wait_ms = 900",
        )
        .unwrap();
        let cluster = cfg.cluster_config().unwrap();
        assert_eq!(
            cluster.remote_shards,
            vec!["hosta:7071".to_string(), "unix:/tmp/b.sock".to_string()]
        );
        assert_eq!(cluster.shard_count(), 2, "remote mode counts addresses, not `shards`");
        assert_eq!(cluster.reconnect.attempts, 5);
        assert_eq!(cluster.reconnect.base_delay, std::time::Duration::from_millis(10));
        assert_eq!(cluster.reconnect.max_delay, std::time::Duration::from_millis(80));
        assert_eq!(cluster.reconnect.total_wait, std::time::Duration::from_millis(900));
        // Malformed remote lists fail loudly at parse time.
        assert!(RunConfig::from_toml("[cluster]\nremote_shards = [1, 2]").is_err());
        assert!(RunConfig::from_toml("[cluster]\nremote_shards = \"hosta:7071\"").is_err());
        assert!(RunConfig::from_toml("[cluster]\nreconnect_attempts = 0").is_err());
    }

    #[test]
    fn cluster_fit_mode_parses_and_rejects_unknowns() {
        let cfg = RunConfig::from_toml("[cluster]\nfit_mode = \"map-reduce\"").unwrap();
        assert_eq!(cfg.cluster_config().unwrap().fit_mode, crate::cluster::FitMode::MapReduce);
        let d = RunConfig::default().cluster_config().unwrap();
        assert_eq!(d.fit_mode, crate::cluster::FitMode::Request);
        assert!(RunConfig::from_toml("[cluster]\nfit_mode = \"mapreduce\"").is_err());
    }

    #[test]
    fn serve_net_section_configures_the_daemon() {
        let cfg = RunConfig::from_toml(
            "[serve.net]\nlisten = \"127.0.0.1:7071\"\nmax_conns = 4\nidle_timeout_ms = 1500\n\
             trace_log = \"/tmp/spans.jsonl\"\nmetrics_listen = \"127.0.0.1:9200\"",
        )
        .unwrap();
        assert_eq!(cfg.serve_listen, "127.0.0.1:7071");
        let net = cfg.net_config().unwrap();
        assert_eq!(net.max_conns, 4);
        assert_eq!(net.idle_timeout_ms, 1500);
        assert_eq!(net.trace_log.as_deref(), Some("/tmp/spans.jsonl"));
        assert_eq!(net.metrics_listen.as_deref(), Some("127.0.0.1:9200"));
        // Defaults: no listener (one-shot mode), idle timeout off, no
        // trace tee, no scrape endpoint.
        let d = RunConfig::default();
        assert!(d.serve_listen.is_empty());
        let dn = d.net_config().unwrap();
        assert_eq!(dn.idle_timeout_ms, 0);
        assert!(dn.trace_log.is_none());
        assert!(dn.metrics_listen.is_none());
    }

    #[test]
    fn profile_flag_parses_and_defaults_off() {
        assert!(!RunConfig::default().profile);
        assert!(RunConfig::from_toml("profile = true").unwrap().profile);
        assert!(RunConfig::from_toml("profile = \"yes\"").is_err());
    }

    #[test]
    fn serve_section_overrides_pool_shape() {
        let cfg = RunConfig::from_toml(
            "[serve]\nworkers = 4\nqueue_capacity = 16\nmax_batch = 2\nshed = \"shed\"",
        )
        .unwrap();
        let serve = cfg.serve_config().unwrap();
        assert_eq!(serve.workers, 4);
        assert_eq!(serve.queue_capacity, 16);
        assert_eq!(serve.max_batch, 2);
        assert_eq!(serve.shed_policy, crate::serve::ShedPolicy::ShedArrivals);
    }

    #[test]
    fn serve_fairness_and_cache_knobs_parse() {
        let cfg = RunConfig::from_toml(
            "[serve]\ntenant_weights = [\"acme=3\", \"free=1\"]\ndefault_tenant_weight = 2\n\
             tenant_queue_cap = 8\ncache_capacity = 16\nmax_tracked_tenants = 10",
        )
        .unwrap();
        let serve = cfg.serve_config().unwrap();
        assert_eq!(serve.tenant_weights.get("acme"), Some(&3));
        assert_eq!(serve.tenant_weights.get("free"), Some(&1));
        assert_eq!(serve.default_tenant_weight, 2);
        assert_eq!(serve.tenant_queue_cap, 8);
        assert_eq!(serve.cache_capacity, 16);
        assert_eq!(serve.max_tracked_tenants, 10);
        // Defaults: no weights, no quota, cache on, 64-tenant cardinality.
        let d = RunConfig::default().serve_config().unwrap();
        assert!(d.tenant_weights.is_empty());
        assert_eq!(d.default_tenant_weight, 1);
        assert_eq!(d.tenant_queue_cap, 0);
        assert_eq!(d.cache_capacity, 64);
        // Malformed entries fail loudly at parse time.
        assert!(RunConfig::from_toml("[serve]\ntenant_weights = [\"acme\"]").is_err());
        assert!(RunConfig::from_toml("[serve]\ntenant_weights = [\"acme=0\"]").is_err());
        assert!(RunConfig::from_toml("[serve]\ntenant_weights = [\"two words=1\"]").is_err());
        assert!(RunConfig::from_toml("[serve]\ntenant_weights = \"acme=1\"").is_err());
        assert!(RunConfig::from_toml("[serve]\ndefault_tenant_weight = 0").is_err());
        assert!(RunConfig::from_toml("[serve]\nmax_tracked_tenants = 0").is_err());
    }

    #[test]
    fn loads_small_synthetic_dataset() {
        let cfg = RunConfig {
            dataset: "blobs".into(),
            max_points: 500,
            ..Default::default()
        };
        let ds = cfg.load_dataset().unwrap();
        assert_eq!(ds.n(), 500);
        // minmax applied by default.
        assert!(ds.points.as_slice().iter().all(|&v| (0.0..=1.0).contains(&v)));
    }
}
