//! Per-phase solver profiling: a low-overhead monotonic [`PhaseTimer`]
//! threaded through the four algorithms and the coordinator driver
//! (DESIGN.md §2).
//!
//! The work-efficiency counters (`kmeans::metrics::WorkEfficiency`) say
//! how much distance work the triangle-inequality filters avoided; the
//! phase timer says where the remaining *time* went — split into the
//! five canonical phases of a fit:
//!
//! | phase    | meaning                                                |
//! |----------|--------------------------------------------------------|
//! | `init`   | seeding + the first full assignment scan               |
//! | `assign` | per-iteration assignment (filter walk + kernel scans)  |
//! | `bounds` | bound maintenance (inflate/deflate after drifts)       |
//! | `update` | centroid recomputation + drift measurement             |
//! | `reduce` | map-reduce partial accumulation / final reduction      |
//!
//! ## The non-perturbation contract (normative)
//!
//! Profiling must be *provably non-perturbing*: a fit with the timer on
//! is bit-identical (assignments, centroid bits, §8 FNV fingerprint) to
//! the same fit with it off. The timer holds that contract by
//! construction — it touches only the monotonic clock and its own
//! nanosecond accumulators, never a point, bound or centroid — and
//! `rust/tests/profile.rs` (`make profile-test`) holds it empirically
//! across all four algorithms.
//!
//! Enablement is a process-wide flag ([`set_enabled`], wired to the
//! `--profile` CLI flag / `profile` config key) sampled once per timer
//! at construction: a disabled timer never reads the clock — every call
//! is a branch on a cold bool, which is what "off ⇒ zero-cost no-op"
//! means here. The resulting [`PhaseTotals`] ride `RunStats` →
//! `RunReport` → `FitSummary` → the §4 wire reply (`phase_*_ms` keys,
//! present only when profiling produced them).

use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Instant;

/// The canonical phases, in wire/reporting order.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    Init = 0,
    Assign = 1,
    Bounds = 2,
    Update = 3,
    Reduce = 4,
}

/// Number of phases (array dimension for [`PhaseTotals`]).
pub const PHASES: usize = 5;

impl Phase {
    pub const ALL: [Phase; PHASES] =
        [Phase::Init, Phase::Assign, Phase::Bounds, Phase::Update, Phase::Reduce];

    /// The phase's wire name (label value for `fit.phase_ms`).
    pub fn name(self) -> &'static str {
        match self {
            Phase::Init => "init",
            Phase::Assign => "assign",
            Phase::Bounds => "bounds",
            Phase::Update => "update",
            Phase::Reduce => "reduce",
        }
    }
}

static ENABLED: AtomicBool = AtomicBool::new(false);

/// Turn per-phase profiling on or off process-wide. Timers sample the
/// flag at construction, so flipping it mid-fit affects only later fits.
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// Whether new [`PhaseTimer`]s will record.
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Accumulated per-phase wall time for one fit, in milliseconds,
/// indexed by [`Phase`].
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct PhaseTotals {
    pub ms: [f64; PHASES],
}

impl PhaseTotals {
    pub fn get(&self, p: Phase) -> f64 {
        self.ms[p as usize]
    }

    /// Sum across phases (the profiled share of the fit's wall time).
    pub fn total_ms(&self) -> f64 {
        self.ms.iter().sum()
    }

    /// Fold another fit's totals in (map-reduce rollup).
    pub fn absorb(&mut self, other: &PhaseTotals) {
        for i in 0..PHASES {
            self.ms[i] += other.ms[i];
        }
    }
}

/// A monotonic stopwatch with one lane per [`Phase`]. `enter` switches
/// the active phase (closing the previous one), `exit` closes it; both
/// are inlineable no-ops when profiling was disabled at construction.
#[derive(Debug)]
pub struct PhaseTimer {
    enabled: bool,
    current: Option<(Phase, Instant)>,
    ns: [u64; PHASES],
}

impl Default for PhaseTimer {
    fn default() -> Self {
        PhaseTimer::new()
    }
}

impl PhaseTimer {
    /// A timer honouring the process-wide [`enabled`] flag.
    pub fn new() -> PhaseTimer {
        PhaseTimer::with_enabled(enabled())
    }

    /// A timer with explicit enablement (tests, benches).
    pub fn with_enabled(on: bool) -> PhaseTimer {
        PhaseTimer { enabled: on, current: None, ns: [0; PHASES] }
    }

    #[inline]
    fn flush(&mut self, now: Instant) {
        if let Some((p, t0)) = self.current.take() {
            self.ns[p as usize] += now.duration_since(t0).as_nanos() as u64;
        }
    }

    /// Start attributing wall time to `p`, closing any open phase.
    #[inline]
    pub fn enter(&mut self, p: Phase) {
        if !self.enabled {
            return;
        }
        let now = Instant::now();
        self.flush(now);
        self.current = Some((p, now));
    }

    /// Close the open phase without opening another (time between `exit`
    /// and the next `enter` is attributed to nothing).
    #[inline]
    pub fn exit(&mut self) {
        if !self.enabled {
            return;
        }
        let now = Instant::now();
        self.flush(now);
    }

    /// Close any open phase and return the totals — `None` when the
    /// timer was disabled, so callers can thread `Option<PhaseTotals>`
    /// straight into reports without an emptiness convention.
    pub fn totals(&mut self) -> Option<PhaseTotals> {
        if !self.enabled {
            return None;
        }
        self.exit();
        let mut t = PhaseTotals::default();
        for i in 0..PHASES {
            t.ms[i] = self.ns[i] as f64 / 1.0e6;
        }
        Some(t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_timer_reports_none_and_never_accumulates() {
        let mut t = PhaseTimer::with_enabled(false);
        t.enter(Phase::Assign);
        std::thread::sleep(std::time::Duration::from_millis(2));
        t.exit();
        assert_eq!(t.totals(), None);
    }

    #[test]
    fn enter_switches_phases_and_totals_accumulate() {
        let mut t = PhaseTimer::with_enabled(true);
        t.enter(Phase::Init);
        std::thread::sleep(std::time::Duration::from_millis(2));
        // enter() closes init and opens assign in one call.
        t.enter(Phase::Assign);
        std::thread::sleep(std::time::Duration::from_millis(2));
        t.exit();
        // Time after exit() is attributed to nothing.
        std::thread::sleep(std::time::Duration::from_millis(2));
        let totals = t.totals().expect("enabled timer yields totals");
        assert!(totals.get(Phase::Init) > 0.0);
        assert!(totals.get(Phase::Assign) > 0.0);
        assert_eq!(totals.get(Phase::Update), 0.0);
        assert!(totals.total_ms() >= totals.get(Phase::Init) + totals.get(Phase::Assign));
        let mut sum = PhaseTotals::default();
        sum.absorb(&totals);
        sum.absorb(&totals);
        assert_eq!(sum.get(Phase::Init), 2.0 * totals.get(Phase::Init));
    }

    #[test]
    fn phase_names_cover_the_wire_order() {
        let names: Vec<&str> = Phase::ALL.iter().map(|p| p.name()).collect();
        assert_eq!(names, vec!["init", "assign", "bounds", "update", "reduce"]);
    }
}
