//! The leveled stderr sink: one parseable diagnostic stream for every
//! process in the stack (CLI, serve daemon, cluster front, shards).
//!
//! Before this module, operational diagnostics were bare `eprintln!`
//! calls scattered through `main.rs` and the cluster supervisor — fine
//! for a CLI, useless for a fleet whose stderr is collected. Every line
//! now has one shape:
//!
//! ```text
//! [<epoch_ms>] [<level>] [<target>] <message>
//! ```
//!
//! The threshold comes from `KPYNQ_LOG` (`error`, `warn`, `info`,
//! `debug`; default `info`), read once on first use. An unknown value
//! falls back to `info` rather than erroring — a typo in an env var must
//! not take a daemon down. No timestamps formatting, no file sinks, no
//! async: stderr is line-buffered enough for diagnostics, and anything
//! heavier belongs in [`super::metrics`] or [`super::trace`].

use std::sync::atomic::{AtomicU8, Ordering};

use super::trace::epoch_ms;

/// Diagnostic severity, most to least severe.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

impl Level {
    pub fn name(self) -> &'static str {
        match self {
            Level::Error => "error",
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Debug => "debug",
        }
    }

    /// Parse a `KPYNQ_LOG` value; `None` for anything unrecognized.
    pub fn from_name(s: &str) -> Option<Level> {
        match s.trim().to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            _ => None,
        }
    }

    fn from_u8(v: u8) -> Level {
        match v {
            0 => Level::Error,
            1 => Level::Warn,
            3 => Level::Debug,
            _ => Level::Info,
        }
    }
}

/// Current threshold, encoded as `Level as u8`; `UNSET` means the env
/// var has not been consulted yet.
static THRESHOLD: AtomicU8 = AtomicU8::new(UNSET);
const UNSET: u8 = u8::MAX;

/// The active threshold, parsing `KPYNQ_LOG` on first call.
pub fn threshold() -> Level {
    match THRESHOLD.load(Ordering::Relaxed) {
        UNSET => {
            let level = std::env::var("KPYNQ_LOG")
                .ok()
                .and_then(|v| Level::from_name(&v))
                .unwrap_or(Level::Info);
            THRESHOLD.store(level as u8, Ordering::Relaxed);
            level
        }
        v => Level::from_u8(v),
    }
}

/// Override the threshold (tests; `--quiet`-style CLI flags).
pub fn set_threshold(level: Level) {
    THRESHOLD.store(level as u8, Ordering::Relaxed);
}

/// Would a record at `level` be emitted?
pub fn enabled(level: Level) -> bool {
    level <= threshold()
}

/// Emit one record to stderr if `level` clears the threshold.
pub fn log(level: Level, target: &str, msg: &str) {
    if enabled(level) {
        eprintln!("[{}] [{}] [{}] {}", epoch_ms(), level.name(), target, msg);
    }
}

pub fn error(target: &str, msg: &str) {
    log(Level::Error, target, msg);
}

pub fn warn(target: &str, msg: &str) {
    log(Level::Warn, target, msg);
}

pub fn info(target: &str, msg: &str) {
    log(Level::Info, target, msg);
}

pub fn debug(target: &str, msg: &str) {
    log(Level::Debug, target, msg);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_names_round_trip() {
        for l in [Level::Error, Level::Warn, Level::Info, Level::Debug] {
            assert_eq!(Level::from_name(l.name()), Some(l));
        }
        assert_eq!(Level::from_name("WARNING"), Some(Level::Warn));
        assert_eq!(Level::from_name("  Debug "), Some(Level::Debug));
        assert_eq!(Level::from_name("verbose"), None);
    }

    #[test]
    fn threshold_orders_severity() {
        // Error is the most severe (lowest discriminant): a `warn`
        // threshold passes error+warn and drops info+debug.
        set_threshold(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        assert!(!enabled(Level::Debug));
        set_threshold(Level::Info); // restore the default for other tests
    }
}
