//! Prometheus exposition: render a registry snapshot as text-format
//! 0.0.4, plus the two helpers behind the `--metrics-listen` HTTP
//! responder (PROTOCOL.md §11).
//!
//! The renderer consumes the *snapshot JSON* — not the registry — on
//! purpose: the cluster front merges shard snapshots at the JSON level
//! (`metrics::merge_snapshot_labeled`), so rendering from JSON means one
//! code path serves a session's own registry, a front's merged fleet
//! snapshot, and the `{"op":"metrics","format":"prometheus"}` wire reply
//! identically.
//!
//! Format notes (text format 0.0.4):
//! * metric names must match `[a-zA-Z_:][a-zA-Z0-9_:]*`, so the dotted
//!   canonical names (`serve.latency_ms`) are transliterated with `.` →
//!   `_` ([`prom_name`]); the `# HELP` line keeps the dotted original so
//!   a scrape can be mapped back to `obs::metrics::names`;
//! * label values escape `\` → `\\`, `"` → `\"` and newline → `\n` —
//!   the same escaping the series encoding uses, shared via
//!   `metrics::escape_label_value`;
//! * histograms emit *cumulative* `_bucket{le="…"}` lines closed by
//!   `le="+Inf"`, plus `_sum` and `_count` — converted from the
//!   snapshot's non-cumulative sparse log2 buckets.

use std::collections::BTreeMap;

use super::metrics::{decode_series, escape_label_value};
use crate::util::json::Json;

/// Transliterate a dotted metric name into the Prometheus name grammar
/// `[a-zA-Z_:][a-zA-Z0-9_:]*` (every illegal character becomes `_`).
pub fn prom_name(name: &str) -> String {
    let mut out = String::with_capacity(name.len());
    for (i, c) in name.chars().enumerate() {
        let ok = c.is_ascii_alphabetic() || c == '_' || c == ':' || (i > 0 && c.is_ascii_digit());
        out.push(if ok { c } else { '_' });
    }
    if out.is_empty() {
        out.push('_');
    }
    out
}

/// Render one label block (`{k="v",…}`, or `""` when empty), with an
/// optional extra pair appended (the histogram `le`). Keys pass through
/// [`prom_name`]; values are escaped.
fn prom_labels(labels: &[(String, String)], extra: Option<(&str, &str)>) -> String {
    if labels.is_empty() && extra.is_none() {
        return String::new();
    }
    let mut out = String::from("{");
    let mut first = true;
    for (k, v) in labels {
        if !first {
            out.push(',');
        }
        first = false;
        out.push_str(&prom_name(k));
        out.push_str("=\"");
        out.push_str(&escape_label_value(v));
        out.push('"');
    }
    if let Some((k, v)) = extra {
        if !first {
            out.push(',');
        }
        out.push_str(k);
        out.push_str("=\"");
        out.push_str(&escape_label_value(v));
        out.push('"');
    }
    out.push('}');
    out
}

/// Format a sample value: integral f64s print without the trailing `.0`
/// JSON-style floats would carry (Prometheus parses either; the integer
/// form is what every textbook exposition looks like).
fn fmt_num(v: f64) -> String {
    if v.fract() == 0.0 && v.abs() < 9.0e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

/// Group one snapshot section's series by base metric name. BTreeMap
/// input + output keeps the rendering deterministic.
fn group_section<'j>(
    section: &'j Json,
) -> BTreeMap<String, Vec<(Vec<(String, String)>, &'j Json)>> {
    let mut grouped: BTreeMap<String, Vec<(Vec<(String, String)>, &Json)>> = BTreeMap::new();
    if let Json::Obj(map) = section {
        for (series, value) in map {
            let (name, labels) = decode_series(series);
            grouped.entry(name).or_default().push((labels, value));
        }
    }
    grouped
}

fn render_scalar_section(out: &mut String, section: &Json, kind: &str) {
    for (name, series) in group_section(section) {
        let pname = prom_name(&name);
        out.push_str(&format!("# HELP {pname} kpynq metric {name}\n"));
        out.push_str(&format!("# TYPE {pname} {kind}\n"));
        for (labels, value) in series {
            let v = value.as_f64().unwrap_or(0.0);
            out.push_str(&format!("{pname}{} {}\n", prom_labels(&labels, None), fmt_num(v)));
        }
    }
}

fn render_histogram_section(out: &mut String, section: &Json) {
    for (name, series) in group_section(section) {
        let pname = prom_name(&name);
        out.push_str(&format!("# HELP {pname} kpynq metric {name}\n"));
        out.push_str(&format!("# TYPE {pname} histogram\n"));
        for (labels, value) in series {
            let count = value.get("count").and_then(|v| v.as_f64()).unwrap_or(0.0);
            let sum = value.get("sum").and_then(|v| v.as_f64()).unwrap_or(0.0);
            let mut cum = 0.0;
            if let Ok(Json::Arr(buckets)) = value.get("buckets") {
                // Snapshot buckets are sparse, non-cumulative and already
                // in ascending `le` order (obs::metrics encoding).
                for b in buckets {
                    let le = b.get("le").and_then(|v| v.as_f64()).unwrap_or(0.0);
                    let n = b.get("n").and_then(|v| v.as_f64()).unwrap_or(0.0);
                    cum += n;
                    out.push_str(&format!(
                        "{pname}_bucket{} {}\n",
                        prom_labels(&labels, Some(("le", &fmt_num(le)))),
                        fmt_num(cum)
                    ));
                }
            }
            out.push_str(&format!(
                "{pname}_bucket{} {}\n",
                prom_labels(&labels, Some(("le", "+Inf"))),
                fmt_num(count)
            ));
            out.push_str(&format!("{pname}_sum{} {}\n", prom_labels(&labels, None), fmt_num(sum)));
            out.push_str(&format!(
                "{pname}_count{} {}\n",
                prom_labels(&labels, None),
                fmt_num(count)
            ));
        }
    }
}

/// Render a `Registry::snapshot()`-shaped JSON object (possibly a merged
/// fleet snapshot) as one Prometheus text-format 0.0.4 body.
pub fn render_prometheus(snapshot: &Json) -> String {
    let mut out = String::new();
    if let Ok(section) = snapshot.get("counters") {
        render_scalar_section(&mut out, section, "counter");
    }
    if let Ok(section) = snapshot.get("gauges") {
        render_scalar_section(&mut out, section, "gauge");
    }
    if let Ok(section) = snapshot.get("histograms") {
        render_histogram_section(&mut out, section);
    }
    out
}

/// Parse the request line of an HTTP/1.1 request head into
/// `(method, path)` — all the routing a read-only scrape endpoint needs.
pub fn parse_request_line(head: &str) -> Option<(&str, &str)> {
    let line = head.lines().next()?;
    let mut parts = line.split_whitespace();
    let method = parts.next()?;
    let path = parts.next()?;
    parts.next()?; // HTTP-version must be present
    Some((method, path))
}

/// Serialize one connection-per-scrape HTTP/1.1 response.
pub fn http_response(status: u16, reason: &str, content_type: &str, body: &str) -> Vec<u8> {
    format!(
        "HTTP/1.1 {status} {reason}\r\nContent-Type: {content_type}\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    )
    .into_bytes()
}

/// The Content-Type a Prometheus scraper expects from text format 0.0.4.
pub const PROM_CONTENT_TYPE: &str = "text/plain; version=0.0.4; charset=utf-8";

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::metrics::Registry;

    #[test]
    fn renders_all_three_kinds_with_escaped_labels() {
        let r = Registry::new();
        r.counter("serve.jobs.submitted").add(3);
        r.counter_with("serve.jobs.submitted", &[("tenant", "a\"b\\c\nd")]).inc();
        r.gauge("serve.queue.depth").set(2);
        let h = r.histogram_with("serve.latency_ms", &[("tenant", "acme")]);
        h.record(0);
        h.record(3);
        h.record(900);
        let body = render_prometheus(&r.snapshot());
        assert!(body.contains("# TYPE serve_jobs_submitted counter\n"));
        assert!(body.contains("serve_jobs_submitted 3\n"));
        // The hostile tenant value is escaped per the 0.0.4 rules.
        assert!(
            body.contains("serve_jobs_submitted{tenant=\"a\\\"b\\\\c\\nd\"} 1\n"),
            "escaping failed:\n{body}"
        );
        assert!(body.contains("# TYPE serve_queue_depth gauge\n"));
        assert!(body.contains("serve_queue_depth 2\n"));
        // Histogram: cumulative buckets closed by +Inf, then sum/count.
        assert!(body.contains("# TYPE serve_latency_ms histogram\n"));
        assert!(body.contains("serve_latency_ms_bucket{tenant=\"acme\",le=\"1\"} 1\n"));
        assert!(body.contains("serve_latency_ms_bucket{tenant=\"acme\",le=\"4\"} 2\n"));
        assert!(body.contains("serve_latency_ms_bucket{tenant=\"acme\",le=\"1024\"} 3\n"));
        assert!(body.contains("serve_latency_ms_bucket{tenant=\"acme\",le=\"+Inf\"} 3\n"));
        assert!(body.contains("serve_latency_ms_sum{tenant=\"acme\"} 903\n"));
        assert!(body.contains("serve_latency_ms_count{tenant=\"acme\"} 3\n"));
        // Names are transliterated into the 0.0.4 grammar: no dots.
        for line in body.lines().filter(|l| !l.starts_with('#')) {
            let name_end = line.find(['{', ' ']).unwrap_or(line.len());
            assert!(
                !line[..name_end].contains('.'),
                "metric name not transliterated: {line}"
            );
        }
    }

    #[test]
    fn rendering_is_deterministic_and_empty_snapshot_is_empty_body() {
        let r = Registry::new();
        assert_eq!(render_prometheus(&r.snapshot()), "");
        r.counter_with("c", &[("shard", "1")]).inc();
        r.counter_with("c", &[("shard", "0")]).inc();
        let a = render_prometheus(&r.snapshot());
        let b = render_prometheus(&r.snapshot());
        assert_eq!(a, b);
        // One HELP/TYPE pair per base name, shared by both series.
        assert_eq!(a.matches("# TYPE c counter").count(), 1);
        let s0 = a.find("c{shard=\"0\"} 1").expect("shard 0 series");
        let s1 = a.find("c{shard=\"1\"} 1").expect("shard 1 series");
        assert!(s0 < s1, "series render in deterministic (BTreeMap) order");
    }

    #[test]
    fn http_helpers_route_a_scrape() {
        let (method, path) =
            parse_request_line("GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        assert_eq!((method, path), ("GET", "/metrics"));
        assert!(parse_request_line("garbage").is_none());
        let resp = http_response(200, "OK", PROM_CONTENT_TYPE, "a 1\n");
        let text = String::from_utf8(resp).unwrap();
        assert!(text.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(text.contains("Content-Length: 4\r\n"));
        assert!(text.ends_with("\r\n\r\na 1\n"));
    }
}
