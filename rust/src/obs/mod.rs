//! `kpynq::obs` — the observability layer: a zero-dependency metrics
//! registry, structured trace spans, and a leveled stderr log sink.
//!
//! Everything above the kernels now runs as a service (serve daemon,
//! cluster front, remote shards), and services are debugged from their
//! telemetry, not their stdout. This module gives the stack one shared
//! vocabulary for that telemetry:
//!
//! - [`metrics`] — process- or session-scoped named counters, gauges and
//!   log2-bucketed histograms behind lock-cheap [`Counter`]/[`Gauge`]/
//!   [`Histogram`] handles, optionally carrying an ordered label set
//!   (`tenant`, `shard`, `algorithm`, `backend`, `priority`, `phase` —
//!   PROTOCOL.md §11), with a snapshot-to-JSON encoder. The serve
//!   session, admission queue, net front and cluster front all register
//!   their counters here instead of hand-threading atomics.
//! - [`profile`] — per-phase solver profiling: a monotonic [`PhaseTimer`]
//!   splitting each fit's wall time into `init`/`assign`/`bounds`/
//!   `update`/`reduce`, off by default and provably non-perturbing
//!   (bit-identical fits either way; DESIGN.md §2).
//! - [`expo`] — Prometheus text-format 0.0.4 rendering of a registry
//!   snapshot, serving `{"op":"metrics","format":"prometheus"}` and the
//!   `--metrics-listen` `GET /metrics` scrape endpoint.
//! - [`trace`] — per-request span events (`admit`, `queue-wait`,
//!   `dispatch`, `reduce-barrier`, `reply`) keyed by a `trace_id` that is
//!   minted at the front (or supplied by the client, PROTOCOL.md §11) and
//!   propagated on every shard-bound frame. Events land in a bounded
//!   in-memory [`TraceRing`], drainable as JSONL via the `{"op":"trace"}`
//!   control frame or `kpynq serve --trace-log <path>` — or read without
//!   consuming via `{"op":"trace","peek":true}`.
//! - [`log`] — a leveled stderr sink (`KPYNQ_LOG=error|warn|info|debug`)
//!   that the CLI, supervisor and remote-fleet diagnostics route through,
//!   so daemon stderr is one parseable stream.
//!
//! Layer contracts live in DESIGN.md §2; the wire-visible parts
//! (`trace_id`, the `trace` frame) are normative in PROTOCOL.md §11.
//!
//! Like the rest of the crate, this module uses only `std` — no tracing
//! or metrics crates, per DESIGN.md §1.

pub mod expo;
pub mod log;
pub mod metrics;
pub mod profile;
pub mod trace;

pub use metrics::{global, Counter, Gauge, Histogram, Registry};
pub use profile::{Phase, PhaseTimer, PhaseTotals};
pub use trace::{mint_trace_id, SpanEvent, TraceRing};
