//! `kpynq::obs` — the observability layer: a zero-dependency metrics
//! registry, structured trace spans, and a leveled stderr log sink.
//!
//! Everything above the kernels now runs as a service (serve daemon,
//! cluster front, remote shards), and services are debugged from their
//! telemetry, not their stdout. This module gives the stack one shared
//! vocabulary for that telemetry:
//!
//! - [`metrics`] — process- or session-scoped named counters, gauges and
//!   log2-bucketed histograms behind lock-cheap [`Counter`]/[`Gauge`]/
//!   [`Histogram`] handles, with a snapshot-to-JSON encoder. The serve
//!   session, admission queue, net front and cluster front all register
//!   their counters here instead of hand-threading atomics.
//! - [`trace`] — per-request span events (`admit`, `queue-wait`,
//!   `dispatch`, `reduce-barrier`, `reply`) keyed by a `trace_id` that is
//!   minted at the front (or supplied by the client, PROTOCOL.md §11) and
//!   propagated on every shard-bound frame. Events land in a bounded
//!   in-memory [`TraceRing`], drainable as JSONL via the `{"op":"trace"}`
//!   control frame or `kpynq serve --trace-log <path>`.
//! - [`log`] — a leveled stderr sink (`KPYNQ_LOG=error|warn|info|debug`)
//!   that the CLI, supervisor and remote-fleet diagnostics route through,
//!   so daemon stderr is one parseable stream.
//!
//! Layer contracts live in DESIGN.md §2; the wire-visible parts
//! (`trace_id`, the `trace` frame) are normative in PROTOCOL.md §11.
//!
//! Like the rest of the crate, this module uses only `std` — no tracing
//! or metrics crates, per DESIGN.md §1.

pub mod log;
pub mod metrics;
pub mod trace;

pub use metrics::{global, Counter, Gauge, Histogram, Registry};
pub use trace::{mint_trace_id, SpanEvent, TraceRing};
