//! Structured trace spans: per-request events keyed by a `trace_id`,
//! buffered in a bounded in-memory ring.
//!
//! A trace is not a span *tree* — the stack is a pipeline, so a flat
//! chain of timestamped events (`admit` → `queue-wait` → `dispatch` →
//! `reduce-barrier`* → `reply`) reconstructs a request's life exactly,
//! including across the front → shard hop: the front mints the
//! `trace_id` (or accepts the client's, PROTOCOL.md §11) and the id
//! rides the shard-bound `FitRequest`/`partial_fit` frames, so one grep
//! over the drained JSONL follows one request through every process.
//!
//! The [`TraceRing`] is deliberately lossy: a fixed-capacity deque that
//! drops its *oldest* events under pressure and counts what it dropped.
//! Observability must never wedge serving — pushing is one short mutex
//! hold, never an allocation spike, never a flush.
//!
//! Draining is destructive and cheap (`swap` out the deque); the
//! `{"op":"trace"}` control frame and `--trace-log` both drain the same
//! ring, so events are delivered exactly once to whoever asks first.
//! A wire scraper that must not steal events from the `--trace-log` tee
//! (or from another scraper) sends `{"op":"trace","peek":true}` instead:
//! [`TraceRing::peek`] copies the buffer and leaves both the events and
//! the dropped counter in place (PROTOCOL.md §11 documents the
//! exactly-once-vs-peek contract).

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{SystemTime, UNIX_EPOCH};

use crate::util::json::Json;

/// Default event capacity of a session's ring.
pub const DEFAULT_RING_CAPACITY: usize = 4096;

/// Milliseconds since the Unix epoch — the timestamp spans carry.
pub fn epoch_ms() -> u64 {
    SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_millis() as u64)
        .unwrap_or(0)
}

/// Mint a 16-hex-char trace id: epoch nanos mixed (splitmix64-style)
/// with a process-local sequence and the pid, so concurrent mints —
/// and mints from different shard processes — never collide in practice
/// without any RNG dependency.
pub fn mint_trace_id() -> String {
    static SEQ: AtomicU64 = AtomicU64::new(0);
    let nanos = SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_nanos() as u64)
        .unwrap_or(0);
    let seq = SEQ.fetch_add(1, Ordering::Relaxed);
    let mut x = nanos ^ seq.rotate_left(32) ^ ((std::process::id() as u64) << 17);
    x ^= x >> 30;
    x = x.wrapping_mul(0xbf58476d1ce4e5b9);
    x ^= x >> 27;
    x = x.wrapping_mul(0x94d049bb133111eb);
    x ^= x >> 31;
    format!("{x:016x}")
}

/// One timestamped trace event. Serialized as a single JSON object —
/// one JSONL line — by [`SpanEvent::to_json`].
#[derive(Clone, Debug)]
pub struct SpanEvent {
    pub trace_id: String,
    /// Event name: `admit`, `queue-wait`, `dispatch`, `reduce-barrier`,
    /// `reply` (PROTOCOL.md §11 lists the normative set).
    pub name: String,
    /// Milliseconds since the Unix epoch at emission.
    pub ts_ms: u64,
    /// Event-specific attributes (job id, shard, epoch, durations).
    pub attrs: BTreeMap<String, Json>,
}

impl SpanEvent {
    /// A new event stamped now, with no attributes yet.
    pub fn new(trace_id: &str, name: &str) -> SpanEvent {
        SpanEvent {
            trace_id: trace_id.to_string(),
            name: name.to_string(),
            ts_ms: epoch_ms(),
            attrs: BTreeMap::new(),
        }
    }

    /// Builder-style attribute attachment.
    pub fn attr(mut self, key: &str, value: Json) -> SpanEvent {
        self.attrs.insert(key.to_string(), value);
        self
    }

    /// Numeric-attribute convenience (ids, shard indices, millisecond
    /// durations all flow through here).
    pub fn num(self, key: &str, value: f64) -> SpanEvent {
        self.attr(key, Json::Num(value))
    }

    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("trace_id".to_string(), Json::Str(self.trace_id.clone()));
        m.insert("event".to_string(), Json::Str(self.name.clone()));
        m.insert("ts_ms".to_string(), Json::Num(self.ts_ms as f64));
        for (k, v) in &self.attrs {
            m.insert(k.clone(), v.clone());
        }
        Json::Obj(m)
    }
}

#[derive(Debug, Default)]
struct RingInner {
    events: VecDeque<SpanEvent>,
    /// Events evicted since the last drain.
    dropped: u64,
}

/// A bounded, drop-oldest buffer of [`SpanEvent`]s. Cloneable via `Arc`
/// at the owner's discretion; all methods take `&self`.
#[derive(Debug)]
pub struct TraceRing {
    capacity: usize,
    inner: Mutex<RingInner>,
}

impl Default for TraceRing {
    fn default() -> Self {
        TraceRing::new(DEFAULT_RING_CAPACITY)
    }
}

impl TraceRing {
    /// A ring holding at most `capacity` events (capacity 0 is clamped
    /// to 1 — a ring that can hold nothing would silently drop forever).
    pub fn new(capacity: usize) -> TraceRing {
        TraceRing { capacity: capacity.max(1), inner: Mutex::new(RingInner::default()) }
    }

    /// Append one event, evicting the oldest if the ring is full.
    pub fn push(&self, event: SpanEvent) {
        let mut inner = self.inner.lock().expect("trace ring poisoned");
        if inner.events.len() >= self.capacity {
            inner.events.pop_front();
            inner.dropped += 1;
        }
        inner.events.push_back(event);
    }

    /// Buffered event count.
    pub fn len(&self) -> usize {
        self.inner.lock().expect("trace ring poisoned").events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Take every buffered event (oldest first) plus the count of events
    /// evicted since the previous drain. Destructive: each event is
    /// delivered exactly once across all drainers.
    pub fn drain(&self) -> (Vec<SpanEvent>, u64) {
        let mut inner = self.inner.lock().expect("trace ring poisoned");
        let events = std::mem::take(&mut inner.events).into();
        let dropped = std::mem::take(&mut inner.dropped);
        (events, dropped)
    }

    /// Copy every buffered event (oldest first) plus the
    /// evicted-since-last-drain count, leaving the ring untouched — the
    /// non-destructive read behind `{"op":"trace","peek":true}`
    /// (PROTOCOL.md §11). A peek never consumes: the same events remain
    /// for the next drain (or the `--trace-log` tee) to deliver
    /// exactly once.
    pub fn peek(&self) -> (Vec<SpanEvent>, u64) {
        let inner = self.inner.lock().expect("trace ring poisoned");
        (inner.events.iter().cloned().collect(), inner.dropped)
    }

    /// Drain into the wire shape of the `{"op":"trace"}` reply
    /// (PROTOCOL.md §11): `{"op":"trace","events":[...],"dropped":N}`.
    pub fn drain_json(&self) -> Json {
        let (events, dropped) = self.drain();
        trace_reply_json(&events, dropped)
    }

    /// Non-destructive variant of [`TraceRing::drain_json`] — the same
    /// wire shape, built from [`TraceRing::peek`].
    pub fn peek_json(&self) -> Json {
        let (events, dropped) = self.peek();
        trace_reply_json(&events, dropped)
    }
}

fn trace_reply_json(events: &[SpanEvent], dropped: u64) -> Json {
    let mut m = BTreeMap::new();
    m.insert("op".to_string(), Json::Str("trace".into()));
    m.insert("events".to_string(), Json::Arr(events.iter().map(SpanEvent::to_json).collect()));
    m.insert("dropped".to_string(), Json::Num(dropped as f64));
    Json::Obj(m)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mint_produces_distinct_16_hex_ids() {
        let a = mint_trace_id();
        let b = mint_trace_id();
        assert_eq!(a.len(), 16);
        assert!(a.chars().all(|c| c.is_ascii_hexdigit()));
        assert_ne!(a, b, "sequence component must separate same-instant mints");
    }

    #[test]
    fn ring_overflow_drops_oldest_and_counts() {
        let ring = TraceRing::new(3);
        for i in 0..5 {
            ring.push(SpanEvent::new("t", "admit").num("id", i as f64));
        }
        assert_eq!(ring.len(), 3);
        let (events, dropped) = ring.drain();
        assert_eq!(dropped, 2);
        let ids: Vec<usize> =
            events.iter().map(|e| e.attrs["id"].as_usize().unwrap()).collect();
        assert_eq!(ids, vec![2, 3, 4], "oldest events are the ones evicted");
        // Dropped counter resets per drain.
        ring.push(SpanEvent::new("t", "reply"));
        let (events, dropped) = ring.drain();
        assert_eq!((events.len(), dropped), (1, 0));
    }

    #[test]
    fn drain_is_destructive_and_ordered() {
        let ring = TraceRing::default();
        ring.push(SpanEvent::new("abc", "admit"));
        ring.push(SpanEvent::new("abc", "reply"));
        let (events, dropped) = ring.drain();
        assert_eq!(dropped, 0);
        assert_eq!(events.len(), 2);
        assert_eq!(events[0].name, "admit");
        assert_eq!(events[1].name, "reply");
        assert!(ring.is_empty());
        assert_eq!(ring.drain().0.len(), 0, "second drain finds nothing");
    }

    #[test]
    fn peek_is_non_destructive_and_preserves_the_dropped_counter() {
        let ring = TraceRing::new(2);
        for i in 0..3 {
            ring.push(SpanEvent::new("t", "admit").num("id", i as f64));
        }
        // Peek twice: identical views, nothing consumed.
        let (e1, d1) = ring.peek();
        let (e2, d2) = ring.peek();
        assert_eq!((e1.len(), d1), (2, 1));
        assert_eq!((e2.len(), d2), (2, 1));
        assert_eq!(ring.len(), 2);
        let j = ring.peek_json();
        assert_eq!(j.get("op").unwrap().as_str().unwrap(), "trace");
        assert_eq!(j.get("dropped").unwrap().as_usize().unwrap(), 1);
        // The drain that follows still delivers every event exactly once.
        let (events, dropped) = ring.drain();
        assert_eq!((events.len(), dropped), (2, 1));
        assert!(ring.is_empty());
    }

    #[test]
    fn drain_json_matches_the_wire_shape() {
        let ring = TraceRing::new(8);
        ring.push(SpanEvent::new("deadbeef00000000", "admit").num("id", 7.0));
        let j = ring.drain_json();
        assert_eq!(j.get("op").unwrap().as_str().unwrap(), "trace");
        assert_eq!(j.get("dropped").unwrap().as_usize().unwrap(), 0);
        let events = j.get("events").unwrap().as_arr().unwrap();
        assert_eq!(events.len(), 1);
        assert_eq!(events[0].get("trace_id").unwrap().as_str().unwrap(), "deadbeef00000000");
        assert_eq!(events[0].get("event").unwrap().as_str().unwrap(), "admit");
        assert_eq!(events[0].get("id").unwrap().as_usize().unwrap(), 7);
        assert!(events[0].get("ts_ms").is_ok());
    }
}
