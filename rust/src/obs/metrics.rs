//! The metrics registry: named counters, gauges and log2-bucketed
//! histograms behind lock-cheap cloneable handles.
//!
//! A [`Registry`] is a name → metric map. Handles ([`Counter`],
//! [`Gauge`], [`Histogram`]) are `Arc`s over atomics: registering takes
//! the registry lock once, but every subsequent `inc`/`set`/`record` is a
//! single atomic op — the hot serving path never contends on the map.
//! Registering a name twice returns the *same* underlying metric, so a
//! queue and a session can share `serve.queue.depth` without plumbing.
//!
//! Registries are instantiable so each serving session owns its own
//! numbers (two daemons embedded in one test process must not merge
//! their `serve.jobs.submitted`); [`global()`] provides the process-wide
//! one for code with no session to hang a registry on.
//!
//! [`Registry::snapshot`] encodes the whole registry as one
//! `util::json::Json` object — the same encoder the wire uses — so a
//! snapshot can be logged, asserted on in tests, or written as
//! `BENCH_<name>.json` by the benches. Every name in [`names`] must be
//! documented (backticked) in README.md or PROTOCOL.md; `tools/
//! check-docs.sh` enforces this.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::util::json::Json;

/// Canonical metric names registered by the stack. Kept in one block so
/// `tools/check-docs.sh` can extract them and assert each is documented.
pub mod names {
    /// Jobs accepted by a local serve session.
    pub const SERVE_JOBS_SUBMITTED: &str = "serve.jobs.submitted";
    /// Current admission-queue depth (all priority lanes).
    pub const SERVE_QUEUE_DEPTH: &str = "serve.queue.depth";
    /// High-water admission-queue depth.
    pub const SERVE_QUEUE_PEAK_DEPTH: &str = "serve.queue.peak_depth";
    /// Jobs shed because the queue was full.
    pub const SERVE_QUEUE_SHED_FULL: &str = "serve.queue.shed_full";
    /// Jobs shed because their deadline expired while queued.
    pub const SERVE_QUEUE_SHED_DEADLINE: &str = "serve.queue.shed_deadline";
    /// Histogram of queue-wait time (ms) over answered jobs.
    pub const SERVE_QUEUE_WAIT_MS: &str = "serve.queue_wait_ms";
    /// Histogram of tenant-observed latency (queue + service, ms).
    pub const SERVE_LATENCY_MS: &str = "serve.latency_ms";
    /// Jobs accepted by a cluster front.
    pub const CLUSTER_JOBS_SUBMITTED: &str = "cluster.jobs.submitted";
    /// Jobs re-queued off a dead shard for re-dispatch.
    pub const CLUSTER_REQUEUES: &str = "cluster.requeues";
    /// Shard daemons restarted by the supervisor.
    pub const CLUSTER_SHARD_RESTARTS: &str = "cluster.shard_restarts";
    /// Remote-shard links re-established after a drop.
    pub const CLUSTER_REMOTE_RECONNECTS: &str = "cluster.remote.reconnects";
}

/// A monotonically increasing counter handle (clone = same counter).
#[derive(Clone, Debug, Default)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    /// A free-standing counter, not attached to any registry — used where
    /// a struct wants counter semantics without naming a metric.
    pub fn new() -> Counter {
        Counter::default()
    }

    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A set-to-current-value gauge handle (clone = same gauge).
#[derive(Clone, Debug, Default)]
pub struct Gauge(Arc<AtomicI64>);

impl Gauge {
    pub fn new() -> Gauge {
        Gauge::default()
    }

    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    /// Raise the gauge to `v` if `v` is higher — the high-water-mark op.
    pub fn set_max(&self, v: i64) {
        self.0.fetch_max(v, Ordering::Relaxed);
    }

    pub fn add(&self, n: i64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Number of log2 buckets: index 0 holds the value 0, index `i ≥ 1`
/// holds values in `[2^(i-1), 2^i)`, up to index 64 (top bit set).
const BUCKETS: usize = 65;

#[derive(Debug)]
struct HistInner {
    count: AtomicU64,
    sum: AtomicU64,
    buckets: [AtomicU64; BUCKETS],
}

impl Default for HistInner {
    fn default() -> Self {
        HistInner {
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            buckets: [(); BUCKETS].map(|_| AtomicU64::new(0)),
        }
    }
}

/// A log2-bucketed histogram handle (clone = same histogram). Buckets
/// double: 0, [1,2), [2,4), [4,8), … — coarse, but latency spans five
/// orders of magnitude and log2 resolution is what capacity planning
/// actually reads.
#[derive(Clone, Debug, Default)]
pub struct Histogram(Arc<HistInner>);

/// Bucket index for a recorded value: 0 for 0, else `64 - leading_zeros`
/// (so bucket `i ≥ 1` covers `[2^(i-1), 2^i)`).
pub fn bucket_index(v: u64) -> usize {
    if v == 0 {
        0
    } else {
        64 - v.leading_zeros() as usize
    }
}

/// Exclusive upper bound of bucket `i`, as f64 (bucket 64's bound, 2^64,
/// does not fit in u64).
pub fn bucket_bound(i: usize) -> f64 {
    if i == 0 {
        1.0
    } else {
        (2.0f64).powi(i as i32)
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram::default()
    }

    pub fn record(&self, v: u64) {
        self.0.count.fetch_add(1, Ordering::Relaxed);
        self.0.sum.fetch_add(v, Ordering::Relaxed);
        self.0.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
    }

    /// Record a millisecond duration given as f64 (negative clamps to 0).
    pub fn record_ms(&self, ms: f64) {
        self.record(if ms > 0.0 { ms as u64 } else { 0 });
    }

    pub fn count(&self) -> u64 {
        self.0.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> u64 {
        self.0.sum.load(Ordering::Relaxed)
    }

    /// Snapshot of the non-empty buckets as `(exclusive upper bound, n)`.
    pub fn buckets(&self) -> Vec<(f64, u64)> {
        (0..BUCKETS)
            .filter_map(|i| {
                let n = self.0.buckets[i].load(Ordering::Relaxed);
                (n > 0).then(|| (bucket_bound(i), n))
            })
            .collect()
    }

    fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("count".to_string(), Json::Num(self.count() as f64));
        m.insert("sum".to_string(), Json::Num(self.sum() as f64));
        let buckets = self
            .buckets()
            .into_iter()
            .map(|(le, n)| {
                let mut b = BTreeMap::new();
                b.insert("le".to_string(), Json::Num(le));
                b.insert("n".to_string(), Json::Num(n as f64));
                Json::Obj(b)
            })
            .collect();
        m.insert("buckets".to_string(), Json::Arr(buckets));
        Json::Obj(m)
    }
}

#[derive(Clone, Debug)]
enum Metric {
    Counter(Counter),
    Gauge(Gauge),
    Histogram(Histogram),
}

/// A name → metric map. See the module docs for scoping guidance.
#[derive(Debug, Default)]
pub struct Registry {
    metrics: Mutex<BTreeMap<String, Metric>>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Get-or-create the counter `name`. If `name` is already registered
    /// as a different metric type, a detached handle is returned (the
    /// snapshot keeps the first registration) — a programming error, but
    /// one that must not panic a serving daemon.
    pub fn counter(&self, name: &str) -> Counter {
        let mut m = self.metrics.lock().expect("metrics registry poisoned");
        match m
            .entry(name.to_string())
            .or_insert_with(|| Metric::Counter(Counter::new()))
        {
            Metric::Counter(c) => c.clone(),
            _ => Counter::new(),
        }
    }

    /// Get-or-create the gauge `name` (type-mismatch rule as [`Registry::counter`]).
    pub fn gauge(&self, name: &str) -> Gauge {
        let mut m = self.metrics.lock().expect("metrics registry poisoned");
        match m
            .entry(name.to_string())
            .or_insert_with(|| Metric::Gauge(Gauge::new()))
        {
            Metric::Gauge(g) => g.clone(),
            _ => Gauge::new(),
        }
    }

    /// Get-or-create the histogram `name` (type-mismatch rule as [`Registry::counter`]).
    pub fn histogram(&self, name: &str) -> Histogram {
        let mut m = self.metrics.lock().expect("metrics registry poisoned");
        match m
            .entry(name.to_string())
            .or_insert_with(|| Metric::Histogram(Histogram::new()))
        {
            Metric::Histogram(h) => h.clone(),
            _ => Histogram::new(),
        }
    }

    /// Encode the registry as one JSON object:
    /// `{"counters":{..},"gauges":{..},"histograms":{..}}`.
    pub fn snapshot(&self) -> Json {
        let m = self.metrics.lock().expect("metrics registry poisoned");
        let mut counters = BTreeMap::new();
        let mut gauges = BTreeMap::new();
        let mut histograms = BTreeMap::new();
        for (name, metric) in m.iter() {
            match metric {
                Metric::Counter(c) => {
                    counters.insert(name.clone(), Json::Num(c.get() as f64));
                }
                Metric::Gauge(g) => {
                    gauges.insert(name.clone(), Json::Num(g.get() as f64));
                }
                Metric::Histogram(h) => {
                    histograms.insert(name.clone(), h.to_json());
                }
            }
        }
        let mut out = BTreeMap::new();
        out.insert("counters".to_string(), Json::Obj(counters));
        out.insert("gauges".to_string(), Json::Obj(gauges));
        out.insert("histograms".to_string(), Json::Obj(histograms));
        Json::Obj(out)
    }
}

/// The process-wide registry, for code with no session registry in reach
/// (CLI paths, benches). Session-scoped counters belong on the session's
/// own [`Registry`].
pub fn global() -> &'static Registry {
    static GLOBAL: OnceLock<Registry> = OnceLock::new();
    GLOBAL.get_or_init(Registry::new)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge_handles_share_state() {
        let r = Registry::new();
        let a = r.counter("jobs");
        let b = r.counter("jobs");
        a.inc();
        b.add(2);
        assert_eq!(a.get(), 3);
        let g = r.gauge("depth");
        g.set(5);
        g.set_max(3); // lower: no-op
        assert_eq!(r.gauge("depth").get(), 5);
        g.set_max(9);
        assert_eq!(g.get(), 9);
        g.add(-4);
        assert_eq!(g.get(), 5);
    }

    #[test]
    fn histogram_bucket_boundaries_are_powers_of_two() {
        // Bucket 0 holds exactly the value 0.
        assert_eq!(bucket_index(0), 0);
        // Bucket i (i ≥ 1) covers [2^(i-1), 2^i): both edges land where
        // the encoder's `le` (exclusive upper bound) says they do.
        assert_eq!(bucket_index(1), 1);
        assert_eq!(bucket_index(2), 2);
        assert_eq!(bucket_index(3), 2);
        assert_eq!(bucket_index(4), 3);
        assert_eq!(bucket_index(7), 3);
        assert_eq!(bucket_index(8), 4);
        assert_eq!(bucket_index(1023), 10);
        assert_eq!(bucket_index(1024), 11);
        assert_eq!(bucket_index(u64::MAX), 64);
        assert_eq!(bucket_bound(0), 1.0);
        assert_eq!(bucket_bound(1), 2.0);
        assert_eq!(bucket_bound(10), 1024.0);
    }

    #[test]
    fn histogram_records_into_the_right_buckets() {
        let h = Histogram::new();
        for v in [0, 1, 1, 2, 3, 900] {
            h.record(v);
        }
        assert_eq!(h.count(), 6);
        assert_eq!(h.sum(), 907);
        let buckets = h.buckets();
        // 0 → bucket 0 (le 1); 1,1 → bucket 1 (le 2); 2,3 → bucket 2
        // (le 4); 900 → bucket 10 (le 1024).
        assert_eq!(buckets, vec![(1.0, 1), (2.0, 2), (4.0, 2), (1024.0, 1)]);
        // record_ms clamps negatives and truncates.
        h.record_ms(-3.5);
        h.record_ms(2.9);
        assert_eq!(h.count(), 8);
        assert_eq!(h.buckets()[0], (1.0, 2));
    }

    #[test]
    fn snapshot_encodes_all_three_kinds() {
        let r = Registry::new();
        r.counter("c").add(7);
        r.gauge("g").set(-2);
        r.histogram("h").record(5);
        let snap = r.snapshot();
        assert_eq!(snap.get("counters").unwrap().get("c").unwrap().as_usize().unwrap(), 7);
        assert_eq!(snap.get("gauges").unwrap().get("g").unwrap().as_f64().unwrap(), -2.0);
        let h = snap.get("histograms").unwrap().get("h").unwrap();
        assert_eq!(h.get("count").unwrap().as_usize().unwrap(), 1);
        assert_eq!(h.get("sum").unwrap().as_usize().unwrap(), 5);
        // The snapshot re-parses through the crate's own JSON codec.
        let text = snap.to_string();
        assert_eq!(Json::parse(&text).unwrap().to_string(), text);
    }

    #[test]
    fn type_mismatch_returns_detached_handle_without_panicking() {
        let r = Registry::new();
        r.counter("x").add(4);
        let g = r.gauge("x"); // wrong type: detached
        g.set(99);
        assert_eq!(
            r.snapshot().get("counters").unwrap().get("x").unwrap().as_usize().unwrap(),
            4
        );
        assert!(r.snapshot().get("gauges").unwrap().get("x").is_err());
    }
}
