//! The metrics registry: named counters, gauges and log2-bucketed
//! histograms behind lock-cheap cloneable handles.
//!
//! A [`Registry`] is a name → metric map. Handles ([`Counter`],
//! [`Gauge`], [`Histogram`]) are `Arc`s over atomics: registering takes
//! the registry lock once, but every subsequent `inc`/`set`/`record` is a
//! single atomic op — the hot serving path never contends on the map.
//! Registering a name twice returns the *same* underlying metric, so a
//! queue and a session can share `serve.queue.depth` without plumbing.
//!
//! Registries are instantiable so each serving session owns its own
//! numbers (two daemons embedded in one test process must not merge
//! their `serve.jobs.submitted`); [`global()`] provides the process-wide
//! one for code with no session to hang a registry on.
//!
//! [`Registry::snapshot`] encodes the whole registry as one
//! `util::json::Json` object — the same encoder the wire uses — so a
//! snapshot can be logged, asserted on in tests, or written as
//! `BENCH_<name>.json` by the benches. Every name in [`names`] must be
//! documented (backticked) in README.md or PROTOCOL.md; `tools/
//! check-docs.sh` enforces this.
//!
//! ## Labels
//!
//! Metrics optionally carry an ordered label set (PROTOCOL.md §11). A
//! labeled metric is registered through [`Registry::counter_with`] /
//! [`Registry::gauge_with`] / [`Registry::histogram_with`]: the (name,
//! labels) pair is interned into one canonical *series key* —
//! `name{key="value",…}` with keys in [`names::LABEL_KEYS`] order and
//! values escaped — under which the series lives in the map. Interning
//! pays the registry lock once; the returned handle is the same
//! single-atomic-op handle unlabeled metrics get, so the hot path cost
//! is identical. `snapshot()` needs no new shape: labeled series appear
//! in the same three sections keyed by their series key, and `BTreeMap`
//! ordering makes the encoding deterministic.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::util::json::Json;

/// Canonical metric names registered by the stack. Kept in one block so
/// `tools/check-docs.sh` can extract them and assert each is documented.
pub mod names {
    /// Jobs accepted by a local serve session.
    pub const SERVE_JOBS_SUBMITTED: &str = "serve.jobs.submitted";
    /// Current admission-queue depth (all priority lanes).
    pub const SERVE_QUEUE_DEPTH: &str = "serve.queue.depth";
    /// High-water admission-queue depth.
    pub const SERVE_QUEUE_PEAK_DEPTH: &str = "serve.queue.peak_depth";
    /// Jobs shed because the queue was full.
    pub const SERVE_QUEUE_SHED_FULL: &str = "serve.queue.shed_full";
    /// Jobs shed because their deadline expired while queued.
    pub const SERVE_QUEUE_SHED_DEADLINE: &str = "serve.queue.shed_deadline";
    /// Histogram of queue-wait time (ms) over answered jobs.
    pub const SERVE_QUEUE_WAIT_MS: &str = "serve.queue_wait_ms";
    /// Histogram of tenant-observed latency (queue + service, ms).
    pub const SERVE_LATENCY_MS: &str = "serve.latency_ms";
    /// Duplicate fits answered from the result cache (PROTOCOL.md §8).
    pub const SERVE_CACHE_HITS: &str = "serve.cache.hits";
    /// Cacheable fits that found no cache entry and ran cold.
    pub const SERVE_CACHE_MISSES: &str = "serve.cache.misses";
    /// Cache entries evicted by the LRU bound.
    pub const SERVE_CACHE_EVICTIONS: &str = "serve.cache.evictions";
    /// Jobs accepted by a cluster front.
    pub const CLUSTER_JOBS_SUBMITTED: &str = "cluster.jobs.submitted";
    /// Jobs re-queued off a dead shard for re-dispatch.
    pub const CLUSTER_REQUEUES: &str = "cluster.requeues";
    /// Shard daemons restarted by the supervisor.
    pub const CLUSTER_SHARD_RESTARTS: &str = "cluster.shard_restarts";
    /// Remote-shard links re-established after a drop.
    pub const CLUSTER_REMOTE_RECONNECTS: &str = "cluster.remote.reconnects";
    /// Histogram of per-fit solver phase wall time (ms), labeled by
    /// `phase` (obs::profile; populated only when profiling is enabled).
    pub const FIT_PHASE_MS: &str = "fit.phase_ms";

    /// The allowed label keys, in canonical encoding order (PROTOCOL.md
    /// §11). Per metric: `tenant` labels `serve.latency_ms`, the two
    /// `serve.queue.shed_*` counters, and the per-tenant
    /// `serve.queue.depth` sub-lane gauges (weighted-fair scheduling,
    /// PROTOCOL.md §7; cardinality capped via `max_tracked_tenants` +
    /// the `~other` overflow label); `shard` labels every series in a
    /// cluster front's merged fleet snapshot; `phase` labels
    /// `fit.phase_ms`; `algorithm`, `backend` and `priority` are
    /// reserved for per-dimension rollups. `tools/check-docs.sh`
    /// requires each key to be documented in PROTOCOL.md.
    pub const LABEL_KEYS: &[&str] =
        &["tenant", "shard", "algorithm", "backend", "priority", "phase"];
}

/// Canonical-order rank of a label key: position in
/// [`names::LABEL_KEYS`], with unknown keys after every known one (then
/// ordered alphabetically among themselves by the encoder).
fn label_rank(key: &str) -> usize {
    names::LABEL_KEYS
        .iter()
        .position(|&k| k == key)
        .unwrap_or(names::LABEL_KEYS.len())
}

/// Escape a label value for the series encoding (shared with the
/// Prometheus exposition format): `\` → `\\`, `"` → `\"`, newline →
/// `\n`.
pub fn escape_label_value(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

fn unescape_label_value(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    let mut chars = v.chars();
    while let Some(c) = chars.next() {
        if c != '\\' {
            out.push(c);
            continue;
        }
        match chars.next() {
            Some('n') => out.push('\n'),
            Some(c) => out.push(c), // covers \\ and \"
            None => out.push('\\'),
        }
    }
    out
}

/// Intern a (name, labels) pair into its canonical series key:
/// `name` alone when unlabeled, else `name{k="v",…}` with keys in
/// [`names::LABEL_KEYS`] order (unknown keys after, alphabetically),
/// duplicate keys last-wins, values escaped by [`escape_label_value`].
pub fn encode_series(name: &str, labels: &[(&str, &str)]) -> String {
    if labels.is_empty() {
        return name.to_string();
    }
    let mut pairs: Vec<(&str, &str)> = Vec::with_capacity(labels.len());
    for &(k, v) in labels {
        if let Some(existing) = pairs.iter_mut().find(|(pk, _)| *pk == k) {
            existing.1 = v; // duplicate key: last wins
        } else {
            pairs.push((k, v));
        }
    }
    pairs.sort_by(|a, b| (label_rank(a.0), a.0).cmp(&(label_rank(b.0), b.0)));
    let mut out = String::from(name);
    out.push('{');
    for (i, (k, v)) in pairs.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(k);
        out.push_str("=\"");
        out.push_str(&escape_label_value(v));
        out.push('"');
    }
    out.push('}');
    out
}

/// Invert [`encode_series`]: split a series key into its base name and
/// decoded `(key, value)` pairs. Tolerant of foreign input: a key with
/// no `{` is an unlabeled series, and a malformed label block decodes
/// to whatever well-formed prefix it has.
pub fn decode_series(series: &str) -> (String, Vec<(String, String)>) {
    let Some(brace) = series.find('{') else {
        return (series.to_string(), Vec::new());
    };
    let name = series[..brace].to_string();
    let body = series[brace + 1..].strip_suffix('}').unwrap_or(&series[brace + 1..]);
    let mut labels = Vec::new();
    let mut rest = body;
    while !rest.is_empty() {
        let Some(eq) = rest.find("=\"") else { break };
        let key = rest[..eq].to_string();
        let val_start = eq + 2;
        // Scan for the closing quote, honouring backslash escapes.
        let bytes = rest.as_bytes();
        let mut i = val_start;
        let mut escaped = false;
        while i < bytes.len() {
            match bytes[i] {
                b'\\' if !escaped => escaped = true,
                b'"' if !escaped => break,
                _ => escaped = false,
            }
            i += 1;
        }
        if i >= bytes.len() {
            break; // unterminated value: drop the malformed tail
        }
        labels.push((key, unescape_label_value(&rest[val_start..i])));
        rest = rest[i + 1..].strip_prefix(',').unwrap_or(&rest[i + 1..]);
    }
    (name, labels)
}

/// Re-encode a series key with one label added (or overwritten) — the
/// cluster front's fleet-merge primitive (PROTOCOL.md §11): every series
/// scraped from shard `i` gains `shard="i"` before entering the merged
/// snapshot.
pub fn relabel_series(series: &str, key: &str, value: &str) -> String {
    let (name, labels) = decode_series(series);
    let mut pairs: Vec<(&str, &str)> =
        labels.iter().map(|(k, v)| (k.as_str(), v.as_str())).collect();
    pairs.push((key, value)); // last wins in encode_series
    encode_series(&name, &pairs)
}

/// Merge a foreign snapshot into `into`, tagging every merged series
/// with `key="value"` first. Sections absent from either side are
/// created/skipped as needed; on a (pathological) series-key collision
/// the merged-in value wins.
pub fn merge_snapshot_labeled(into: &mut Json, snapshot: &Json, key: &str, value: &str) {
    let Json::Obj(dst) = into else { return };
    for section in ["counters", "gauges", "histograms"] {
        let Ok(Json::Obj(src)) = snapshot.get(section) else { continue };
        let entry = dst
            .entry(section.to_string())
            .or_insert_with(|| Json::Obj(BTreeMap::new()));
        if let Json::Obj(dst_map) = entry {
            for (series, v) in src {
                dst_map.insert(relabel_series(series, key, value), v.clone());
            }
        }
    }
}

/// A monotonically increasing counter handle (clone = same counter).
#[derive(Clone, Debug, Default)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    /// A free-standing counter, not attached to any registry — used where
    /// a struct wants counter semantics without naming a metric.
    pub fn new() -> Counter {
        Counter::default()
    }

    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A set-to-current-value gauge handle (clone = same gauge).
#[derive(Clone, Debug, Default)]
pub struct Gauge(Arc<AtomicI64>);

impl Gauge {
    pub fn new() -> Gauge {
        Gauge::default()
    }

    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    /// Raise the gauge to `v` if `v` is higher — the high-water-mark op.
    pub fn set_max(&self, v: i64) {
        self.0.fetch_max(v, Ordering::Relaxed);
    }

    pub fn add(&self, n: i64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Number of log2 buckets: index 0 holds the value 0, index `i ≥ 1`
/// holds values in `[2^(i-1), 2^i)`, up to index 64 (top bit set).
const BUCKETS: usize = 65;

#[derive(Debug)]
struct HistInner {
    count: AtomicU64,
    sum: AtomicU64,
    buckets: [AtomicU64; BUCKETS],
}

impl Default for HistInner {
    fn default() -> Self {
        HistInner {
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            buckets: [(); BUCKETS].map(|_| AtomicU64::new(0)),
        }
    }
}

/// A log2-bucketed histogram handle (clone = same histogram). Buckets
/// double: 0, [1,2), [2,4), [4,8), … — coarse, but latency spans five
/// orders of magnitude and log2 resolution is what capacity planning
/// actually reads.
#[derive(Clone, Debug, Default)]
pub struct Histogram(Arc<HistInner>);

/// Bucket index for a recorded value: 0 for 0, else `64 - leading_zeros`
/// (so bucket `i ≥ 1` covers `[2^(i-1), 2^i)`).
pub fn bucket_index(v: u64) -> usize {
    if v == 0 {
        0
    } else {
        64 - v.leading_zeros() as usize
    }
}

/// Exclusive upper bound of bucket `i`, as f64 (bucket 64's bound, 2^64,
/// does not fit in u64).
pub fn bucket_bound(i: usize) -> f64 {
    if i == 0 {
        1.0
    } else {
        (2.0f64).powi(i as i32)
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram::default()
    }

    pub fn record(&self, v: u64) {
        self.0.count.fetch_add(1, Ordering::Relaxed);
        self.0.sum.fetch_add(v, Ordering::Relaxed);
        self.0.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
    }

    /// Record a millisecond duration given as f64 (negative clamps to 0).
    pub fn record_ms(&self, ms: f64) {
        self.record(if ms > 0.0 { ms as u64 } else { 0 });
    }

    pub fn count(&self) -> u64 {
        self.0.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> u64 {
        self.0.sum.load(Ordering::Relaxed)
    }

    /// Snapshot of the non-empty buckets as `(exclusive upper bound, n)`.
    pub fn buckets(&self) -> Vec<(f64, u64)> {
        (0..BUCKETS)
            .filter_map(|i| {
                let n = self.0.buckets[i].load(Ordering::Relaxed);
                (n > 0).then(|| (bucket_bound(i), n))
            })
            .collect()
    }

    fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("count".to_string(), Json::Num(self.count() as f64));
        m.insert("sum".to_string(), Json::Num(self.sum() as f64));
        let buckets = self
            .buckets()
            .into_iter()
            .map(|(le, n)| {
                let mut b = BTreeMap::new();
                b.insert("le".to_string(), Json::Num(le));
                b.insert("n".to_string(), Json::Num(n as f64));
                Json::Obj(b)
            })
            .collect();
        m.insert("buckets".to_string(), Json::Arr(buckets));
        Json::Obj(m)
    }
}

#[derive(Clone, Debug)]
enum Metric {
    Counter(Counter),
    Gauge(Gauge),
    Histogram(Histogram),
}

/// A name → metric map. See the module docs for scoping guidance.
#[derive(Debug, Default)]
pub struct Registry {
    metrics: Mutex<BTreeMap<String, Metric>>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Get-or-create the counter `name`. If `name` is already registered
    /// as a different metric type, a detached handle is returned (the
    /// snapshot keeps the first registration) — a programming error, but
    /// one that must not panic a serving daemon.
    pub fn counter(&self, name: &str) -> Counter {
        let mut m = self.metrics.lock().expect("metrics registry poisoned");
        match m
            .entry(name.to_string())
            .or_insert_with(|| Metric::Counter(Counter::new()))
        {
            Metric::Counter(c) => c.clone(),
            _ => Counter::new(),
        }
    }

    /// Get-or-create the gauge `name` (type-mismatch rule as [`Registry::counter`]).
    pub fn gauge(&self, name: &str) -> Gauge {
        let mut m = self.metrics.lock().expect("metrics registry poisoned");
        match m
            .entry(name.to_string())
            .or_insert_with(|| Metric::Gauge(Gauge::new()))
        {
            Metric::Gauge(g) => g.clone(),
            _ => Gauge::new(),
        }
    }

    /// Get-or-create the histogram `name` (type-mismatch rule as [`Registry::counter`]).
    pub fn histogram(&self, name: &str) -> Histogram {
        let mut m = self.metrics.lock().expect("metrics registry poisoned");
        match m
            .entry(name.to_string())
            .or_insert_with(|| Metric::Histogram(Histogram::new()))
        {
            Metric::Histogram(h) => h.clone(),
            _ => Histogram::new(),
        }
    }

    /// Get-or-create the counter `name` carrying `labels` (PROTOCOL.md
    /// §11). The pair is interned via [`encode_series`]; hold the handle
    /// — every subsequent `inc`/`add` is the same single atomic op an
    /// unlabeled counter costs.
    pub fn counter_with(&self, name: &str, labels: &[(&str, &str)]) -> Counter {
        self.counter(&encode_series(name, labels))
    }

    /// Labeled variant of [`Registry::gauge`] (see [`Registry::counter_with`]).
    pub fn gauge_with(&self, name: &str, labels: &[(&str, &str)]) -> Gauge {
        self.gauge(&encode_series(name, labels))
    }

    /// Labeled variant of [`Registry::histogram`] (see [`Registry::counter_with`]).
    pub fn histogram_with(&self, name: &str, labels: &[(&str, &str)]) -> Histogram {
        self.histogram(&encode_series(name, labels))
    }

    /// Encode the registry as one JSON object:
    /// `{"counters":{..},"gauges":{..},"histograms":{..}}`.
    pub fn snapshot(&self) -> Json {
        let m = self.metrics.lock().expect("metrics registry poisoned");
        let mut counters = BTreeMap::new();
        let mut gauges = BTreeMap::new();
        let mut histograms = BTreeMap::new();
        for (name, metric) in m.iter() {
            match metric {
                Metric::Counter(c) => {
                    counters.insert(name.clone(), Json::Num(c.get() as f64));
                }
                Metric::Gauge(g) => {
                    gauges.insert(name.clone(), Json::Num(g.get() as f64));
                }
                Metric::Histogram(h) => {
                    histograms.insert(name.clone(), h.to_json());
                }
            }
        }
        let mut out = BTreeMap::new();
        out.insert("counters".to_string(), Json::Obj(counters));
        out.insert("gauges".to_string(), Json::Obj(gauges));
        out.insert("histograms".to_string(), Json::Obj(histograms));
        Json::Obj(out)
    }
}

/// The process-wide registry, for code with no session registry in reach
/// (CLI paths, benches). Session-scoped counters belong on the session's
/// own [`Registry`].
pub fn global() -> &'static Registry {
    static GLOBAL: OnceLock<Registry> = OnceLock::new();
    GLOBAL.get_or_init(Registry::new)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge_handles_share_state() {
        let r = Registry::new();
        let a = r.counter("jobs");
        let b = r.counter("jobs");
        a.inc();
        b.add(2);
        assert_eq!(a.get(), 3);
        let g = r.gauge("depth");
        g.set(5);
        g.set_max(3); // lower: no-op
        assert_eq!(r.gauge("depth").get(), 5);
        g.set_max(9);
        assert_eq!(g.get(), 9);
        g.add(-4);
        assert_eq!(g.get(), 5);
    }

    #[test]
    fn histogram_bucket_boundaries_are_powers_of_two() {
        // Bucket 0 holds exactly the value 0.
        assert_eq!(bucket_index(0), 0);
        // Bucket i (i ≥ 1) covers [2^(i-1), 2^i): both edges land where
        // the encoder's `le` (exclusive upper bound) says they do.
        assert_eq!(bucket_index(1), 1);
        assert_eq!(bucket_index(2), 2);
        assert_eq!(bucket_index(3), 2);
        assert_eq!(bucket_index(4), 3);
        assert_eq!(bucket_index(7), 3);
        assert_eq!(bucket_index(8), 4);
        assert_eq!(bucket_index(1023), 10);
        assert_eq!(bucket_index(1024), 11);
        assert_eq!(bucket_index(u64::MAX), 64);
        assert_eq!(bucket_bound(0), 1.0);
        assert_eq!(bucket_bound(1), 2.0);
        assert_eq!(bucket_bound(10), 1024.0);
    }

    #[test]
    fn histogram_records_into_the_right_buckets() {
        let h = Histogram::new();
        for v in [0, 1, 1, 2, 3, 900] {
            h.record(v);
        }
        assert_eq!(h.count(), 6);
        assert_eq!(h.sum(), 907);
        let buckets = h.buckets();
        // 0 → bucket 0 (le 1); 1,1 → bucket 1 (le 2); 2,3 → bucket 2
        // (le 4); 900 → bucket 10 (le 1024).
        assert_eq!(buckets, vec![(1.0, 1), (2.0, 2), (4.0, 2), (1024.0, 1)]);
        // record_ms clamps negatives and truncates.
        h.record_ms(-3.5);
        h.record_ms(2.9);
        assert_eq!(h.count(), 8);
        assert_eq!(h.buckets()[0], (1.0, 2));
    }

    #[test]
    fn snapshot_encodes_all_three_kinds() {
        let r = Registry::new();
        r.counter("c").add(7);
        r.gauge("g").set(-2);
        r.histogram("h").record(5);
        let snap = r.snapshot();
        assert_eq!(snap.get("counters").unwrap().get("c").unwrap().as_usize().unwrap(), 7);
        assert_eq!(snap.get("gauges").unwrap().get("g").unwrap().as_f64().unwrap(), -2.0);
        let h = snap.get("histograms").unwrap().get("h").unwrap();
        assert_eq!(h.get("count").unwrap().as_usize().unwrap(), 1);
        assert_eq!(h.get("sum").unwrap().as_usize().unwrap(), 5);
        // The snapshot re-parses through the crate's own JSON codec.
        let text = snap.to_string();
        assert_eq!(Json::parse(&text).unwrap().to_string(), text);
    }

    #[test]
    fn labeled_handles_intern_to_one_series() {
        let r = Registry::new();
        let a = r.counter_with("serve.latency_ms", &[("tenant", "acme")]);
        let b = r.counter_with("serve.latency_ms", &[("tenant", "acme")]);
        a.inc();
        b.add(4);
        assert_eq!(a.get(), 5, "same (name, labels) ⇒ same underlying counter");
        // A different label value is a different series.
        let c = r.counter_with("serve.latency_ms", &[("tenant", "umbrella")]);
        c.inc();
        assert_eq!(c.get(), 1);
        // The unlabeled series is independent of every labeled one.
        r.counter("serve.latency_ms").add(7);
        let snap = r.snapshot();
        let counters = snap.get("counters").unwrap();
        assert_eq!(counters.get("serve.latency_ms").unwrap().as_usize().unwrap(), 7);
        assert_eq!(
            counters.get("serve.latency_ms{tenant=\"acme\"}").unwrap().as_usize().unwrap(),
            5
        );
    }

    #[test]
    fn series_encoding_orders_canonically_and_round_trips_escapes() {
        // Keys are emitted in names::LABEL_KEYS order regardless of the
        // order the caller passed them in; unknown keys come last.
        assert_eq!(
            encode_series("m", &[("phase", "assign"), ("tenant", "t"), ("zz", "x")]),
            "m{tenant=\"t\",phase=\"assign\",zz=\"x\"}"
        );
        // Duplicate key: last wins.
        assert_eq!(encode_series("m", &[("tenant", "a"), ("tenant", "b")]), "m{tenant=\"b\"}");
        // The three escape-worthy characters round-trip through
        // encode → decode exactly.
        let hostile = "a\"b\\c\nd";
        let series = encode_series("m", &[("tenant", hostile)]);
        assert_eq!(series, "m{tenant=\"a\\\"b\\\\c\\nd\"}");
        let (name, labels) = decode_series(&series);
        assert_eq!(name, "m");
        assert_eq!(labels, vec![("tenant".to_string(), hostile.to_string())]);
        // Unlabeled keys decode to an empty label list.
        assert_eq!(decode_series("plain.name"), ("plain.name".to_string(), Vec::new()));
    }

    #[test]
    fn relabel_inserts_in_canonical_position_and_overwrites() {
        assert_eq!(relabel_series("m", "shard", "2"), "m{shard=\"2\"}");
        assert_eq!(
            relabel_series("m{tenant=\"t\",phase=\"init\"}", "shard", "0"),
            "m{tenant=\"t\",shard=\"0\",phase=\"init\"}"
        );
        assert_eq!(relabel_series("m{shard=\"9\"}", "shard", "front"), "m{shard=\"front\"}");
    }

    #[test]
    fn merge_snapshot_labeled_tags_every_foreign_series() {
        let front = Registry::new();
        front.counter("cluster.jobs.submitted").add(3);
        let shard = Registry::new();
        shard.counter("serve.jobs.submitted").add(2);
        shard.histogram_with("serve.latency_ms", &[("tenant", "acme")]).record(5);
        let mut merged = front.snapshot();
        merge_snapshot_labeled(&mut merged, &shard.snapshot(), "shard", "1");
        let counters = merged.get("counters").unwrap();
        assert!(counters.get("cluster.jobs.submitted").is_ok(), "front series untouched");
        assert_eq!(
            counters.get("serve.jobs.submitted{shard=\"1\"}").unwrap().as_usize().unwrap(),
            2
        );
        let hists = merged.get("histograms").unwrap();
        let labeled = hists.get("serve.latency_ms{tenant=\"acme\",shard=\"1\"}").unwrap();
        assert_eq!(labeled.get("count").unwrap().as_usize().unwrap(), 1);
    }

    #[test]
    fn snapshot_with_labels_is_deterministic() {
        let build = || {
            let r = Registry::new();
            r.counter_with("c", &[("shard", "1"), ("tenant", "b")]).inc();
            r.counter_with("c", &[("tenant", "a")]).inc();
            r.gauge("g").set(2);
            r.snapshot().to_string()
        };
        assert_eq!(build(), build(), "same registrations ⇒ byte-identical snapshot");
    }

    #[test]
    fn type_mismatch_returns_detached_handle_without_panicking() {
        let r = Registry::new();
        r.counter("x").add(4);
        let g = r.gauge("x"); // wrong type: detached
        g.set(99);
        assert_eq!(
            r.snapshot().get("counters").unwrap().get("x").unwrap().as_usize().unwrap(),
            4
        );
        assert!(r.snapshot().get("gauges").unwrap().get("x").is_err());
    }
}
