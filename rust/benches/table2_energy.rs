//! T2 — "150.90× better energy-efficiency on average … up to 218×".
//!
//! Same runs as T1, energy view: E_cpu / E_fpga with the calibrated power
//! model (§hw::energy — the paper's numbers imply a ~51× power ratio;
//! energy-efficiency ≈ speedup × power ratio).

use kpynq::harness;
use kpynq::hw::energy::PowerModel;
use kpynq::hw::AccelConfig;
use kpynq::kmeans::KMeansConfig;
use kpynq::util::bench::{self, Table};
use kpynq::util::stats::geomean;

fn bench_points() -> usize {
    std::env::var("KPYNQ_BENCH_POINTS").ok().and_then(|v| v.parse().ok()).unwrap_or(12_000)
}

fn main() {
    println!("== T2: energy-efficiency vs optimized CPU standard K-means ==");
    let suite = harness::bench_suite(2019, bench_points());
    let kcfg = KMeansConfig { k: 16, seed: 7, max_iters: 100, ..Default::default() };
    let acfg = AccelConfig::default();
    let cpu = harness::default_cpu();
    let power = PowerModel::default();

    let mut t = Table::new(&[
        "dataset", "cpu (J)", "kpynq (J)", "energy-eff", "speedup", "board W",
    ]);
    let mut effs = Vec::new();
    for ds in &suite {
        let row = harness::speedup_energy_row(ds, &kcfg, &acfg, &cpu).unwrap();
        effs.push(row.energy_efficiency);
        t.row(vec![
            row.dataset.clone(),
            format!("{:.3}", row.cpu_joules),
            format!("{:.5}", row.fpga_joules),
            format!("{:.1}x", row.energy_efficiency),
            format!("{:.2}x", row.speedup),
            format!("{:.2}", row.fpga_joules / row.fpga_seconds.max(1e-12)),
        ]);
    }
    bench::record_table("energy-efficiency", &t);
    t.print();
    println!(
        "geomean energy-eff {:.1}x (max {:.1}x) | operating-point power ratio {:.1}x",
        geomean(&effs),
        effs.iter().cloned().fold(0.0, f64::max),
        power.operating_power_ratio()
    );
    println!("paper: avg 150.90x, max 218x (implied power ratio ~51x)");
    assert!(effs.iter().all(|&e| e > 10.0), "energy-efficiency must be large");
    let path = bench::write_bench_json("table2_energy").expect("bench json");
    println!("wrote {path}");
}
