//! Map-reduce fit — shard-count sweep of one sliced fit
//! (EXPERIMENTS.md §Serving, PROTOCOL.md §10).
//!
//! Drives `cluster::fit_sliced` — the in-process reference for the
//! map-reduce reduction loop — over one fixed fit at increasing shard
//! counts, and holds every row to bit-identity with the solo
//! `kmeans::fit`. The shard states run *sequentially* on this one
//! thread, so the sweep does not measure distributed speedup (that
//! comes from shards being separate processes/hosts); it measures what
//! slicing itself **costs**: per-shard bound-state duplication, the
//! per-epoch exact-sum reduction, and the loss of cross-slice pruning
//! (each shard's triangle-inequality bounds only see its own slice).
//! Read the `vs solo` column as reduction overhead — the price paid per
//! epoch for a partitioning that provably cannot move the bits. Knobs:
//!
//! * `KPYNQ_BENCH_POINTS` — dataset size (default 20 000)
//! * `KPYNQ_MAPREDUCE_K`  — cluster count (default 16)

use std::time::Instant;

use kpynq::cluster::fit_sliced;
use kpynq::data::synth;
use kpynq::kmeans::{self, Algorithm, KMeansConfig};
use kpynq::serve::job::assignments_checksum;
use kpynq::util::bench::{self, Table};

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    let points = env_usize("KPYNQ_BENCH_POINTS", 20_000);
    let k = env_usize("KPYNQ_MAPREDUCE_K", 16);
    let ds = synth::blobs(points, 16, 8, 42);
    let cfg = KMeansConfig { k, seed: 7, max_iters: 50, ..Default::default() };

    let t0 = Instant::now();
    let solo = kmeans::fit(Algorithm::Yinyang, &ds, &cfg).expect("solo fit");
    let solo_ms = t0.elapsed().as_secs_f64() * 1e3;
    let want_fnv = assignments_checksum(&solo.assignments);
    println!(
        "cluster_mapreduce: {points} points x d=16, k={k}, yinyang; \
         solo {solo_ms:.1} ms, {} iters",
        solo.iterations
    );

    let mut t = Table::new(&["shards", "wall ms", "vs solo", "iters", "bit-identical"]);
    for shards in [1usize, 2, 4, 8] {
        let t1 = Instant::now();
        let fit = fit_sliced(Algorithm::Yinyang, &ds, &cfg, shards).expect("sliced fit");
        let ms = t1.elapsed().as_secs_f64() * 1e3;
        let identical = assignments_checksum(&fit.assignments) == want_fnv
            && fit.inertia.to_bits() == solo.inertia.to_bits()
            && fit.iterations == solo.iterations;
        t.row(vec![
            shards.to_string(),
            format!("{ms:.1}"),
            format!("{:.2}x", ms / solo_ms),
            fit.iterations.to_string(),
            identical.to_string(),
        ]);
        assert!(identical, "{shards}-shard slicing diverged from the solo fit");
    }
    bench::record_table("mapreduce-scaling", &t);
    t.print();
    let path = bench::write_bench_json("cluster_mapreduce").expect("bench json");
    println!("wrote {path}");
}
