//! Hot-path microbenchmarks — the §Perf anchor (EXPERIMENTS.md §Perf).
//!
//! Real wall-clock on this host for the L3 paths that dominate profiles:
//!
//! * `sq_dist` — the scalar distance kernel (vectorisation check);
//! * `scan_all` — one point against k centroids;
//! * software iterations — lloyd vs yinyang on a mid-size mixture;
//! * the cycle simulator itself (host cost of a simulated fit);
//! * coordinator tile dispatch through the native and XLA engines.
//!
//! Run before/after every optimisation; keep if >5% on the affected row.

use std::path::PathBuf;

use kpynq::coordinator::driver::run_with_engine;
use kpynq::data::{normalize, synth};
use kpynq::hw::{AccelConfig, Accelerator};
use kpynq::kmeans::{self, init, Algorithm, KMeansConfig};
use kpynq::runtime::native::NativeEngine;
use kpynq::runtime::xla::XlaEngine;
use kpynq::runtime::Engine;
use kpynq::kmeans::kernel;
use kpynq::util::bench::{self, black_box, Bencher, Table};
use kpynq::util::matrix::{sq_dist, Matrix};
use kpynq::util::rng::Rng;

fn main() {
    let b = Bencher::default();
    let e2e = Bencher::end_to_end();

    // --- scalar kernels ---
    let x: Vec<f32> = (0..128).map(|i| i as f32 * 0.01).collect();
    let y: Vec<f32> = (0..128).map(|i| (128 - i) as f32 * 0.02).collect();
    b.bench("sq_dist/d=128 (x1000)", || {
        let mut acc = 0.0f32;
        for _ in 0..1000 {
            acc += sq_dist(black_box(&x), black_box(&y));
        }
        acc
    });

    let mut ds = synth::uci("mnist", 3).unwrap().subsample(20_000, 3);
    normalize::min_max(&mut ds);
    let kcfg = KMeansConfig { k: 16, seed: 7, max_iters: 25, ..Default::default() };
    let cents = init::initialize(&ds, &kcfg).unwrap();
    b.bench("scan_all/d=64,k=16 (x1000)", || {
        let mut acc = 0usize;
        for i in 0..1000 {
            acc += kmeans::kernel::scan_all(black_box(ds.points.row(i)), black_box(&cents)).0;
        }
        acc
    });

    // --- tiled kernel: tile-size × (d, k) sweep (EXPERIMENTS.md §Perf) ---
    // Every timed configuration is first proven bit-identical to the
    // scalar per-point scan — a sweep row that changed results would be
    // measuring a different computation (DESIGN.md §5 contract).
    kernel_tile_sweep(&b);

    // --- software algorithm end-to-end (the CPU comparator's real cost) ---
    e2e.bench("fit/lloyd mnist@20k k=16", || {
        kmeans::fit_from(Algorithm::Lloyd, &ds, &kcfg, cents.clone()).unwrap().iterations
    });
    e2e.bench("fit/yinyang mnist@20k k=16", || {
        kmeans::fit_from(Algorithm::Yinyang, &ds, &kcfg, cents.clone()).unwrap().iterations
    });
    e2e.bench("fit/elkan mnist@20k k=16", || {
        kmeans::fit_from(Algorithm::Elkan, &ds, &kcfg, cents.clone()).unwrap().iterations
    });

    // --- profiling overhead (DESIGN.md §2: annotation, not perturbation) ---
    // The same fit with the per-phase timers off vs on. Bit-identity is
    // asserted before either configuration is timed — an overhead number
    // for a fit that changed results would be meaningless — and the
    // median ratio is printed against the §2 budget (<2%).
    {
        use kpynq::obs::profile;
        profile::set_enabled(false);
        let base = kmeans::fit_from(Algorithm::Yinyang, &ds, &kcfg, cents.clone()).unwrap();
        profile::set_enabled(true);
        let prof = kmeans::fit_from(Algorithm::Yinyang, &ds, &kcfg, cents.clone()).unwrap();
        assert_eq!(prof.assignments, base.assignments, "profiled fit perturbed assignments");
        assert_eq!(
            prof.inertia.to_bits(),
            base.inertia.to_bits(),
            "profiled fit perturbed inertia"
        );

        profile::set_enabled(false);
        let off = e2e.bench("fit/yinyang mnist@20k k=16 profile=off", || {
            kmeans::fit_from(Algorithm::Yinyang, &ds, &kcfg, cents.clone()).unwrap().iterations
        });
        profile::set_enabled(true);
        let on = e2e.bench("fit/yinyang mnist@20k k=16 profile=on", || {
            kmeans::fit_from(Algorithm::Yinyang, &ds, &kcfg, cents.clone()).unwrap().iterations
        });
        profile::set_enabled(false);
        let overhead = on.median_secs() / off.median_secs() - 1.0;
        println!("profiling overhead: {:+.2}% (budget <2%, DESIGN.md §2)", overhead * 100.0);
    }

    // --- the simulator's own host cost ---
    let acc = Accelerator::new(AccelConfig::default());
    e2e.bench("simulate/fpga mnist@20k k=16", || {
        acc.run_fit(&ds, &kcfg, cents.clone()).unwrap().total_cycles
    });

    // --- coordinator + engines ---
    e2e.bench("coordinator/native mnist@20k k=16", || {
        run_with_engine(&mut NativeEngine, &ds, &kcfg).unwrap().fit.iterations
    });

    let artifact_dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    match XlaEngine::new(&artifact_dir) {
        Ok(mut eng) => {
            // Warm the compile cache so the bench measures the request path.
            let tile = ds.points.gather_rows(&(0..256).collect::<Vec<_>>());
            eng.assign_tile(&tile, &cents).unwrap();
            b.bench("engine/xla assign_tile 256x64 k=16", || {
                eng.assign_tile(black_box(&tile), black_box(&cents)).unwrap().idx[0]
            });
            let mut native = NativeEngine;
            b.bench("engine/native assign_tile 256x64 k=16", || {
                native.assign_tile(black_box(&tile), black_box(&cents)).unwrap().idx[0]
            });
            e2e.bench("coordinator/xla mnist@20k k=16", || {
                run_with_engine(&mut eng, &ds, &kcfg).unwrap().fit.iterations
            });
        }
        Err(e) => println!(
            "xla benches skipped ({e}); vendor the `xla` crate and enable the `xla` \
             feature (see Cargo.toml), then run `make artifacts` first"
        ),
    }
    let path = bench::write_bench_json("hotpath").expect("bench json");
    println!("wrote {path}");
}

/// Tile-size sweep for the batch distance kernel: n = 4096 points against
/// (d, k) in {(8, 8), (64, 16), (128, 32)}, tiles (points × centroids) in
/// {(8, 4), (32, 8), (128, 32)} plus the production default. Each cell is
/// asserted bit-identical to the scalar `scan_all` reference per row
/// before it is timed, then recorded into the hotpath bench JSON.
fn kernel_tile_sweep(b: &Bencher) {
    const N: usize = 4096;
    let shapes: [(usize, usize); 3] = [(8, 8), (64, 16), (128, 32)];
    let tiles: [(usize, usize); 3] = [(8, 4), (32, 8), (128, 32)];

    let mut table = Table::new(&["shape", "tile", "median", "bit-identical"]);
    for (d, k) in shapes {
        let mut rng = Rng::new(0xBE2C ^ ((d as u64) << 8) ^ k as u64);
        let pts: Vec<f32> = (0..N * d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let cts: Vec<f32> = (0..k * d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let points = Matrix::from_vec(pts, N, d).unwrap();
        let cents = Matrix::from_vec(cts, k, d).unwrap();

        let mut idx = vec![0u32; N];
        let mut best = vec![0.0f32; N];
        let mut second = vec![0.0f32; N];
        for (tp, tc) in tiles {
            // Correctness gate: bit-identity per row vs the scalar scan.
            kernel::nearest_into_tiled(&points, 0, N, &cents, tp, tc, &mut idx, &mut best, &mut second);
            for i in 0..N {
                let (arg, b0, s0) = kernel::scan_all(points.row(i), &cents);
                assert_eq!(idx[i], arg as u32, "tile ({tp},{tc}) d={d} k={k} row {i}: argmin");
                assert_eq!(
                    best[i].to_bits(),
                    b0.to_bits(),
                    "tile ({tp},{tc}) d={d} k={k} row {i}: best bits"
                );
                assert_eq!(
                    second[i].to_bits(),
                    s0.to_bits(),
                    "tile ({tp},{tc}) d={d} k={k} row {i}: second bits"
                );
            }
            let m = b.bench(&format!("kernel/nearest n=4096 d={d} k={k} tile={tp}x{tc}"), || {
                kernel::nearest_into_tiled(
                    black_box(&points),
                    0,
                    N,
                    black_box(&cents),
                    tp,
                    tc,
                    &mut idx,
                    &mut best,
                    &mut second,
                )
            });
            table.row(vec![
                format!("d={d} k={k}"),
                format!("{tp}x{tc}"),
                format!("{:.3} ms", m.median_secs() * 1e3),
                "yes".into(),
            ]);
        }
    }
    table.print();
    bench::record_table("kernel_tile_sweep", &table);
}
