//! Serving throughput — the `kpynq::serve` pool across shapes (§Perf).
//!
//! Sweeps worker shards × micro-batch cap over a fixed multi-tenant job
//! stream and reports jobs/sec, tail latency and pool utilization straight
//! from the `ServeReport` (the session's own wall-clock — a serving bench
//! measures the system, not one hot loop). Knobs:
//!
//! * `KPYNQ_SERVE_JOBS`   — job count per session (default 24)
//! * `KPYNQ_BENCH_POINTS` — points per job dataset (default 2 000)
//!
//! Rows to watch: batch=8 vs batch=1 at the same worker count isolates the
//! coalescing win; workers 1→2→4 at batch=8 isolates sharding scalability.

use kpynq::kmeans::KMeansConfig;
use kpynq::serve::{FitRequest, Priority, ServeConfig, Server, ShedPolicy};
use kpynq::util::bench::{self, Table};

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// A multi-tenant stream: every job is a distinct (seed, k) tenant on the
/// same d=16 generator family, so compatible jobs can coalesce while no
/// two jobs share a clustering.
fn job_stream(n: usize, points: usize) -> Vec<FitRequest> {
    (0..n)
        .map(|i| FitRequest {
            id: i as u64,
            max_points: points,
            data_seed: 1000 + i as u64,
            kmeans: KMeansConfig {
                k: 4 + (i % 3) * 2,
                seed: 7 + i as u64,
                max_iters: 40,
                ..Default::default()
            },
            priority: match i % 3 {
                0 => Priority::High,
                1 => Priority::Normal,
                _ => Priority::Low,
            },
            ..Default::default()
        })
        .collect()
}

fn main() {
    let jobs = env_usize("KPYNQ_SERVE_JOBS", 24);
    let points = env_usize("KPYNQ_BENCH_POINTS", 2_000);
    println!("serve_throughput: {jobs} jobs x {points} points, native engine shards");

    let mut t = Table::new(&[
        "workers", "batch", "ok", "jobs/s", "p50 ms", "p95 ms", "busy %", "coalesced",
    ]);
    for (workers, max_batch) in [(1, 1), (1, 8), (2, 1), (2, 8), (4, 8)] {
        let cfg = ServeConfig {
            workers,
            queue_capacity: 64,
            max_batch,
            shed_policy: ShedPolicy::Block,
        };
        let server = Server::new(cfg).expect("valid config");
        // Warm run (page cache, allocator) then the measured session.
        server.run(job_stream(jobs.min(4), points)).expect("warmup serve");
        let outcome = server.run(job_stream(jobs, points)).expect("serve");
        let r = &outcome.report;
        assert_eq!(r.completed, jobs as u64, "bench stream must fully complete");
        t.row(vec![
            workers.to_string(),
            max_batch.to_string(),
            r.completed.to_string(),
            format!("{:.2}", r.throughput_jobs_per_sec()),
            format!("{:.1}", r.p50_latency_ms),
            format!("{:.1}", r.p95_latency_ms),
            format!("{:.1}", r.pool_utilization() * 100.0),
            r.batched_jobs.to_string(),
        ]);
    }
    bench::record_table("pool-throughput", &t);
    t.print();
    let path = bench::write_bench_json("serve_throughput").expect("bench json");
    println!("wrote {path}");
}
