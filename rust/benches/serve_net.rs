//! Daemon throughput — the socket front-end across client counts
//! (EXPERIMENTS.md §Serving).
//!
//! Spins one loopback daemon per row, fans out N concurrent NDJSON
//! clients, and measures end-to-end jobs/sec as seen from the *client*
//! side of the socket (connect + submit + read every response), then
//! cross-checks against the daemon's own `ServeReport`. The interesting
//! comparison is against `serve_throughput` (the in-process pool): the
//! delta is the wire + framing cost, and the client-count sweep shows
//! whether one shared session really amortizes engines across
//! connections. Knobs:
//!
//! * `KPYNQ_NET_JOBS`     — jobs per client (default 8)
//! * `KPYNQ_BENCH_POINTS` — points per job dataset (default 2 000)

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::Instant;

use kpynq::serve::net::{Daemon, NetConfig};
use kpynq::serve::ServeConfig;
use kpynq::util::bench::{self, Table};

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// One client session: submit `jobs` requests, read `jobs` responses.
fn run_client(addr: &str, tenant: usize, jobs: usize, points: usize) {
    let stream = TcpStream::connect(addr).expect("connect");
    let mut reader = BufReader::new(stream.try_clone().expect("clone"));
    let mut writer = stream;
    let mut line = String::new();
    reader.read_line(&mut line).expect("greeting");
    for i in 0..jobs {
        let line = format!(
            r#"{{"id": {i}, "data_seed": {}, "max_points": {points}, "k": {}, "seed": {}, "max_iters": 40}}"#,
            1000 + 100 * tenant + i,
            4 + (i % 3) * 2,
            7 + tenant + i,
        );
        writer.write_all(line.as_bytes()).expect("send");
        writer.write_all(b"\n").expect("send");
    }
    for _ in 0..jobs {
        line.clear();
        assert!(reader.read_line(&mut line).expect("response") > 0, "daemon hung up");
        assert!(line.contains("\"status\":\"ok\""), "unexpected response: {line}");
    }
}

fn main() {
    let jobs = env_usize("KPYNQ_NET_JOBS", 8);
    let points = env_usize("KPYNQ_BENCH_POINTS", 2_000);
    println!("serve_net: {jobs} jobs/client x {points} points, loopback TCP, native shards");

    let mut t = Table::new(&[
        "clients", "workers", "ok", "jobs/s", "p50 ms", "p95 ms", "peak conns",
    ]);
    for clients in [1usize, 2, 4, 8] {
        let serve = ServeConfig { workers: 4, queue_capacity: 64, ..Default::default() };
        let daemon = Daemon::bind("127.0.0.1:0", NetConfig::default(), serve).expect("bind");
        let addr = daemon.local_addr();
        let handle = daemon.handle();
        let daemon_thread = std::thread::spawn(move || daemon.run().expect("daemon"));

        // Warm the engine banks (and the page cache) outside the clock.
        let warm = 2.min(jobs);
        run_client(&addr, 99, warm, points);

        let t0 = Instant::now();
        std::thread::scope(|scope| {
            for tenant in 0..clients {
                let addr = &addr;
                scope.spawn(move || run_client(addr, tenant, jobs, points));
            }
        });
        let wall = t0.elapsed().as_secs_f64();

        handle.shutdown();
        let report = daemon_thread.join().expect("daemon join");
        let total = (clients * jobs) as f64;
        t.row(vec![
            clients.to_string(),
            report.workers.to_string(),
            // Exclude the warmup client's jobs from the displayed count so
            // the column matches the jobs/s denominator.
            (report.completed - warm as u64).to_string(),
            format!("{:.2}", total / wall),
            format!("{:.1}", report.p50_latency_ms),
            format!("{:.1}", report.p95_latency_ms),
            report.peak_connections.to_string(),
        ]);
    }
    bench::record_table("daemon-throughput", &t);
    t.print();
    let path = bench::write_bench_json("serve_net").expect("bench json");
    println!("wrote {path}");
}
