//! Cluster fan-out — shard-count sweep through one front door
//! (EXPERIMENTS.md §Serving).
//!
//! Spins a `kpynq cluster` per row (real shard child processes exec'd
//! from this build's `kpynq` binary), fans a fixed client load through
//! the single front endpoint, and measures end-to-end jobs/sec as the
//! clients see them. Read against the `serve_net` rows: a 1-shard
//! cluster vs the plain daemon is the forwarding overhead (one extra
//! socket hop per job), and rising shard counts show whether whole-
//! process shards scale warm-engine capacity the way in-process workers
//! do. The job mix alternates two BatchKeys so the router's affinity
//! actually partitions work instead of round-robining it. Knobs:
//!
//! * `KPYNQ_CLUSTER_JOBS`  — jobs per client (default 8)
//! * `KPYNQ_BENCH_POINTS`  — points per job dataset (default 2 000)
//!
//! Requires running via cargo (`cargo bench --bench cluster_fanout`):
//! the shard binary is located through `CARGO_BIN_EXE_kpynq`.

use std::path::PathBuf;
use std::time::Instant;

use kpynq::cluster::{ClientConn, Cluster, ClusterConfig};
use kpynq::serve::{FitRequest, JobStatus, NetConfig, ServeConfig};
use kpynq::util::bench::{self, Table};

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// One client session over the front door: submit, then drain.
fn run_client(addr: &str, tenant: usize, jobs: usize, points: usize) {
    let mut c = ClientConn::connect(addr).expect("connect front");
    for i in 0..jobs {
        let req = FitRequest {
            id: i as u64,
            // Alternate keys (blobs d=16 / kegg d=20): two affinity pins.
            dataset: if i % 2 == 0 { "blobs".into() } else { "kegg".into() },
            data_seed: (1000 + 100 * tenant + i) as u64,
            max_points: points,
            kmeans: kpynq::kmeans::KMeansConfig {
                k: 4 + (i % 3) * 2,
                seed: (7 + tenant + i) as u64,
                max_iters: 40,
                ..Default::default()
            },
            ..Default::default()
        };
        c.submit(&req).expect("submit");
    }
    for _ in 0..jobs {
        let r = c.recv_response().expect("response");
        assert_eq!(r.status, JobStatus::Ok, "unexpected response: {}", r.detail);
    }
}

fn main() {
    let jobs = env_usize("KPYNQ_CLUSTER_JOBS", 8);
    let points = env_usize("KPYNQ_BENCH_POINTS", 2_000);
    let clients = 4usize;
    println!(
        "cluster_fanout: {clients} clients x {jobs} jobs x {points} points, \
         loopback TCP front, unix-socket shards"
    );

    let mut t = Table::new(&[
        "shards", "workers/shard", "ok", "jobs/s", "p50 ms", "p95 ms", "restarts",
    ]);
    for shards in [1usize, 2, 4] {
        let cfg = ClusterConfig {
            shards,
            serve: ServeConfig { workers: 2, queue_capacity: 64, ..Default::default() },
            socket_dir: std::env::temp_dir()
                .join(format!("kpynq-fanout-{}-{shards}", std::process::id())),
            program: PathBuf::from(env!("CARGO_BIN_EXE_kpynq")),
            ..Default::default()
        };
        let workers = cfg.serve.workers;
        let cluster =
            Cluster::start("127.0.0.1:0", NetConfig::default(), cfg).expect("cluster start");
        let addr = cluster.local_addr();
        let handle = cluster.handle();
        let cluster_thread = std::thread::spawn(move || cluster.run().expect("cluster run"));

        // Warm the shard engine banks outside the clock.
        run_client(&addr, 99, 2.min(jobs), points);

        let t0 = Instant::now();
        std::thread::scope(|scope| {
            for tenant in 0..clients {
                let addr = &addr;
                scope.spawn(move || run_client(addr, tenant, jobs, points));
            }
        });
        let wall = t0.elapsed().as_secs_f64();

        handle.shutdown();
        let report = cluster_thread.join().expect("cluster join");
        let total = (clients * jobs) as f64;
        t.row(vec![
            shards.to_string(),
            workers.to_string(),
            (report.completed - 2.min(jobs) as u64).to_string(),
            format!("{:.2}", total / wall),
            format!("{:.1}", report.p50_latency_ms),
            format!("{:.1}", report.p95_latency_ms),
            report.shard_restarts.to_string(),
        ]);
    }
    bench::record_table("fanout", &t);
    t.print();
    let path = bench::write_bench_json("cluster_fanout").expect("bench json");
    println!("wrote {path}");
}
