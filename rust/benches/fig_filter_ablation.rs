//! F2 — the "work-efficient" claim, quantified: distance-computation work
//! ratios for {standard, point-level filter, multi-level filter, Elkan}
//! plus simulated cycles with the hardware filter on/off.
//!
//! Expected shape: lloyd = 100%; point-level (Hamerly) well below;
//! multi-level (Yinyang, the paper's design) at or below point-level;
//! Elkan lowest in software but with per-point O(k) state — the
//! irregularity the paper's hardware design avoids. Includes `uniform`
//! noise as the adversarial lower bound on filter effectiveness.

use kpynq::data::{normalize, synth};
use kpynq::harness;
use kpynq::hw::AccelConfig;
use kpynq::kmeans::KMeansConfig;
use kpynq::util::bench::{self, Table};

fn bench_points() -> usize {
    std::env::var("KPYNQ_BENCH_POINTS").ok().and_then(|v| v.parse().ok()).unwrap_or(12_000)
}

fn main() {
    println!("== F2: multi-level filter ablation (fraction of n*k*iters distance work) ==");
    let mut suite = harness::bench_suite(2019, bench_points());
    let mut adversarial = synth::uniform(bench_points().min(20_000), 16, 2019);
    normalize::min_max(&mut adversarial);
    suite.push(adversarial);

    let kcfg = KMeansConfig { k: 16, seed: 7, max_iters: 60, ..Default::default() };
    let acfg = AccelConfig::default();

    let mut t = Table::new(&[
        "dataset", "lloyd", "point-level", "multi-level", "elkan", "hw cycles off",
        "hw cycles on", "hw gain",
    ]);
    for ds in &suite {
        let row = harness::filter_ablation_row(ds, &kcfg, &acfg).unwrap();
        t.row(vec![
            row.dataset.clone(),
            format!("{:.1}%", row.lloyd * 100.0),
            format!("{:.1}%", row.point_level * 100.0),
            format!("{:.1}%", row.multi_level * 100.0),
            format!("{:.1}%", row.elkan * 100.0),
            row.cycles_off.to_string(),
            row.cycles_on.to_string(),
            format!("{:.2}x", row.cycles_off as f64 / row.cycles_on as f64),
        ]);
    }
    bench::record_table("filter-ablation", &t);
    t.print();
    println!(
        "reading: the multi-level filter removes the bulk of distance work after the \
         first (full-scan) iteration; uniform noise is the worst case."
    );
    let path = bench::write_bench_json("fig_filter_ablation").expect("bench json");
    println!("wrote {path}");
}
