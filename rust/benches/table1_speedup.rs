//! T1 — the paper's headline: "KPynq consistently excels an optimized
//! CPU-based standard K-means implementation with 2.95× speedup … on
//! average across the six real-life datasets".
//!
//! Regenerates the speedup column for the six UCI-equivalents: simulated
//! Pynq-Z1 KPynq vs the CPU-model baseline, shared trajectory. Datasets
//! are subsampled to `KPYNQ_BENCH_POINTS` (default 12000) to keep the
//! bench budget sane; `examples/uci_clustering.rs` runs full size.
//!
//! Expected shape (not absolute numbers): every row > 1×, geomean in the
//! ~2–4× band, larger wins on higher-d / better-separated datasets where
//! the filter bites hardest.

use kpynq::harness::{self, render_speedup_table};
use kpynq::hw::AccelConfig;
use kpynq::kmeans::KMeansConfig;
use kpynq::util::bench::{self, Bencher};

fn bench_points() -> usize {
    std::env::var("KPYNQ_BENCH_POINTS").ok().and_then(|v| v.parse().ok()).unwrap_or(12_000)
}

fn main() {
    println!("== T1: speedup vs optimized CPU standard K-means ==");
    let suite = harness::bench_suite(2019, bench_points());
    let kcfg = KMeansConfig { k: 16, seed: 7, max_iters: 100, ..Default::default() };
    let acfg = AccelConfig::default();
    let cpu = harness::default_cpu();
    let bencher = Bencher::end_to_end();

    let mut rows = Vec::new();
    for ds in &suite {
        // Also time the simulation itself (host cost of the cycle model).
        let m = bencher.bench(&format!("simulate/{}", ds.name), || {
            harness::speedup_energy_row(ds, &kcfg, &acfg, &cpu).unwrap()
        });
        let row = harness::speedup_energy_row(ds, &kcfg, &acfg, &cpu).unwrap();
        let _ = m;
        rows.push(row);
    }
    println!();
    print!("{}", render_speedup_table(&rows));
    println!("paper: avg 2.95x, max 4.2x (their testbed; shape comparison only)");
    assert!(
        rows.iter().all(|r| r.speedup > 1.0),
        "KPynq must beat the CPU baseline on every dataset"
    );
    let path = bench::write_bench_json("table1_speedup").expect("bench json");
    println!("wrote {path}");
}
