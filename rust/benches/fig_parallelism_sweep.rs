//! F3 — "tunable parameters (e.g. degree of parallelism) … handle various
//! datasets": lane-count sweep on the XC7Z020 with the resource gate.
//!
//! Expected shape: simulated time improves with lanes until either (a) the
//! AXIS/DMA stream or the filter stage becomes the bottleneck — the knee —
//! or (b) the configuration stops fitting the part (DSP or BRAM binds).
//! Low-d datasets knee early (stream-bound); high-d datasets keep scaling
//! longer (compute-bound).

use kpynq::harness;
use kpynq::hw::ZynqPart;
use kpynq::kmeans::KMeansConfig;
use kpynq::util::bench::{self, Table};

fn bench_points() -> usize {
    std::env::var("KPYNQ_BENCH_POINTS").ok().and_then(|v| v.parse().ok()).unwrap_or(12_000)
}

fn main() {
    println!("== F3: degree-of-parallelism sweep on XC7Z020 (mac_width = 4) ==");
    let suite = harness::bench_suite(2019, bench_points());
    let kcfg = KMeansConfig { k: 16, seed: 7, max_iters: 60, ..Default::default() };
    let part = ZynqPart::xc7z020();

    for ds in &suite {
        println!("dataset {} (n={}, d={}):", ds.name, ds.n(), ds.d());
        let mut t = Table::new(&["lanes", "DSP", "BRAM_18K", "fits", "cycles", "speedup vs P=1"]);
        let mut base: Option<u64> = None;
        for lanes in [1u64, 2, 4, 8, 16, 32, 64] {
            let p = harness::parallelism_point(ds, &kcfg, lanes, 4, &part).unwrap();
            let (cyc, spd) = match p.cycles {
                Some(c) => {
                    if base.is_none() {
                        base = Some(c);
                    }
                    (c.to_string(), format!("{:.2}x", base.unwrap() as f64 / c as f64))
                }
                None => ("-".into(), "-".into()),
            };
            t.row(vec![
                lanes.to_string(),
                p.dsp.to_string(),
                p.bram.to_string(),
                if p.fits { "yes".into() } else { "NO".into() },
                cyc,
                spd,
            ]);
        }
        bench::record_table(&format!("sweep-{}", ds.name), &t);
        t.print();
    }
    let path = bench::write_bench_json("fig_parallelism_sweep").expect("bench json");
    println!("wrote {path}");
}
