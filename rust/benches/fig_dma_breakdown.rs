//! F5 — where the cycles go: DMA-in vs filter vs distance pipeline vs PS
//! update, plus the double-buffering overlap gain.
//!
//! Expected shape: low-d datasets (roadnetwork) are stream-dominated; the
//! filter keeps the pipeline share small everywhere after iteration 1;
//! overlap gain > 1 shows the double-buffered AXIS schedule hiding
//! transfer behind compute, exactly what the BRAM double-buffers pay for.

use kpynq::harness;
use kpynq::hw::AccelConfig;
use kpynq::kmeans::KMeansConfig;
use kpynq::util::bench::{self, Table};

fn bench_points() -> usize {
    std::env::var("KPYNQ_BENCH_POINTS").ok().and_then(|v| v.parse().ok()).unwrap_or(12_000)
}

fn main() {
    println!("== F5: iteration cycle breakdown (simulated XC7Z020, filters on) ==");
    let suite = harness::bench_suite(2019, bench_points());
    let kcfg = KMeansConfig { k: 16, seed: 7, max_iters: 60, ..Default::default() };
    let acfg = AccelConfig::default();

    let mut t = Table::new(&[
        "dataset", "dma-in", "filter", "pipeline", "ps-update", "overlap gain",
    ]);
    for ds in &suite {
        let row = harness::dma_breakdown_row(ds, &kcfg, &acfg).unwrap();
        t.row(vec![
            row.dataset.clone(),
            format!("{:.1}%", row.dma_in_frac * 100.0),
            format!("{:.1}%", row.filter_frac * 100.0),
            format!("{:.1}%", row.pipeline_frac * 100.0),
            format!("{:.1}%", row.ps_update_frac * 100.0),
            format!("{:.2}x", row.overlap_gain),
        ]);
    }
    bench::record_table("dma-breakdown", &t);
    t.print();
    println!("(stage shares of serial cycle sum; overlap gain = serial / makespan)");
    let path = bench::write_bench_json("fig_dma_breakdown").expect("bench json");
    println!("wrote {path}");
}
