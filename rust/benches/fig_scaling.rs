//! F4 — "large-size, high-dimension datasets": speedup vs. the CPU
//! baseline over an (n, d, k) grid of synthetic mixtures.
//!
//! Expected shape: speedup grows with d (the filter saves O(d) work per
//! skipped distance while bound checks stay O(1)) and with k (more
//! centroids to skip); it is flattest for tiny d where the AXIS stream
//! dominates — matching the paper's focus on large/high-dimension data.

use kpynq::data::synth::MixtureSpec;
use kpynq::data::normalize;
use kpynq::harness;
use kpynq::hw::AccelConfig;
use kpynq::kmeans::KMeansConfig;
use kpynq::util::bench::{self, Table};

fn scale(base: usize) -> usize {
    let cap: usize = std::env::var("KPYNQ_BENCH_POINTS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(12_000);
    base.min(cap)
}

fn grid_dataset(n: usize, d: usize, seed: u64) -> kpynq::data::Dataset {
    let mut ds = MixtureSpec {
        name: "grid",
        n,
        d,
        modes: 24,
        center_spread: 8.0,
        noise_frac: 0.15,
        imbalance: 0.3,
        active_dims_frac: 0.8,
    }
    .generate(seed);
    normalize::min_max(&mut ds);
    ds
}

fn main() {
    println!("== F4: scaling with n, d, k (speedup vs CPU standard K-means) ==");
    let acfg = AccelConfig::default();
    let cpu = harness::default_cpu();

    println!("-- dimensionality sweep (n = {}, k = 16) --", scale(12_000));
    let mut t = Table::new(&["d", "speedup", "work ratio", "energy-eff"]);
    for d in [2usize, 8, 32, 64, 128] {
        let ds = grid_dataset(scale(12_000), d, 31);
        let kcfg = KMeansConfig { k: 16, seed: 7, max_iters: 60, ..Default::default() };
        let r = harness::speedup_energy_row(&ds, &kcfg, &acfg, &cpu).unwrap();
        t.row(vec![
            d.to_string(),
            format!("{:.2}x", r.speedup),
            format!("{:.1}%", r.work_ratio * 100.0),
            format!("{:.1}x", r.energy_efficiency),
        ]);
    }
    bench::record_table("dimensionality-sweep", &t);
    t.print();

    println!("-- cluster-count sweep (n = {}, d = 32) --", scale(12_000));
    let mut t = Table::new(&["k", "groups", "speedup", "work ratio"]);
    for k in [4usize, 16, 64] {
        let ds = grid_dataset(scale(12_000), 32, 37);
        let kcfg = KMeansConfig { k, seed: 7, max_iters: 60, ..Default::default() };
        let r = harness::speedup_energy_row(&ds, &kcfg, &acfg, &cpu).unwrap();
        t.row(vec![
            k.to_string(),
            kcfg.effective_groups().to_string(),
            format!("{:.2}x", r.speedup),
            format!("{:.1}%", r.work_ratio * 100.0),
        ]);
    }
    bench::record_table("cluster-count-sweep", &t);
    t.print();

    println!("-- size sweep (d = 32, k = 16) --");
    let mut t = Table::new(&["n", "speedup", "sim ms", "cpu ms"]);
    for n in [2_000usize, 8_000, 32_000] {
        let ds = grid_dataset(n, 32, 41);
        let kcfg = KMeansConfig { k: 16, seed: 7, max_iters: 60, ..Default::default() };
        let r = harness::speedup_energy_row(&ds, &kcfg, &acfg, &cpu).unwrap();
        t.row(vec![
            n.to_string(),
            format!("{:.2}x", r.speedup),
            format!("{:.2}", r.fpga_seconds * 1e3),
            format!("{:.2}", r.cpu_seconds * 1e3),
        ]);
    }
    bench::record_table("size-sweep", &t);
    t.print();
    let path = bench::write_bench_json("fig_scaling").expect("bench json");
    println!("wrote {path}");
}
